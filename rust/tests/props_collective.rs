//! Property tests for the collective schedule builders: per-rank volumes
//! match the closed-form collective formulas, every recv has a matching
//! send, and the dependency structure is deadlock-free — across
//! randomized rank counts, node layouts and buffer sizes.

use sauron::config::{CollOp, CollScope, CollectiveSpec};
use sauron::testkit::{forall, Choice, IntRange, Pair, Triple, VecGen};
use sauron::traffic::collective::{self, Step};

/// |actual - expected| within a rounding tolerance of one byte per shard
/// boundary (uneven shards differ by ≤ 1 byte; empty shards are bumped
/// to 1-byte control messages).
fn close(actual: u64, expected: f64, slack: u64) -> Result<(), String> {
    let diff = (actual as f64 - expected).abs();
    if diff <= slack as f64 {
        Ok(())
    } else {
        Err(format!("volume {actual} vs closed form {expected:.1} (slack {slack})"))
    }
}

#[test]
fn prop_ring_allreduce_volumes_match_closed_form() {
    let gen = Pair(IntRange { lo: 2, hi: 24 }, IntRange { lo: 1, hi: 1 << 20 });
    forall(0xC011, 60, &gen, |&(n, size)| {
        let n = n as u32;
        let sched = collective::ring_allreduce(n, size).map_err(|e| e.to_string())?;
        sched.check()?;
        let expect = 2.0 * (n as f64 - 1.0) / n as f64 * size as f64;
        for r in 0..n {
            close(sched.sent_bytes(r), expect, 4 * n as u64)?;
            close(sched.recv_bytes(r), expect, 4 * n as u64)?;
            // Dependency count: 2(n-1) recvs per rank.
            if sched.recv_count(r) != 2 * (n as usize - 1) {
                return Err(format!("rank {r}: {} recvs", sched.recv_count(r)));
            }
        }
        // Global conservation is exact (sends and recvs are the same
        // multiset of messages).
        let sent: u64 = (0..n).map(|r| sched.sent_bytes(r)).sum();
        let recv: u64 = (0..n).map(|r| sched.recv_bytes(r)).sum();
        if sent != recv {
            return Err(format!("global sent {sent} != recv {recv}"));
        }
        Ok(())
    });
}

#[test]
fn prop_allgather_and_alltoall_volumes_match_closed_form() {
    let gen = Triple(
        Choice(&[CollOp::AllGather, CollOp::ReduceScatter, CollOp::AllToAll]),
        IntRange { lo: 2, hi: 20 },
        IntRange { lo: 1, hi: 1 << 20 },
    );
    forall(0xA11, 60, &gen, |&(op, n, size)| {
        let n = n as u32;
        let sched = match op {
            CollOp::AllGather => collective::ring_allgather(n, size),
            CollOp::ReduceScatter => collective::ring_reduce_scatter(n, size),
            CollOp::AllToAll => collective::all_to_all(n, size),
            _ => unreachable!(),
        }
        .map_err(|e| e.to_string())?;
        sched.check()?;
        let expect = (n as f64 - 1.0) / n as f64 * size as f64;
        for r in 0..n {
            close(sched.sent_bytes(r), expect, 4 * n as u64)
                .map_err(|e| format!("{op:?} rank {r}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_hierarchical_volumes_split_intra_vs_inter() {
    let gen = Triple(
        IntRange { lo: 2, hi: 8 },  // nodes
        IntRange { lo: 1, hi: 8 },  // accels per node
        IntRange { lo: 1, hi: 1 << 22 },
    );
    forall(0x41E2, 50, &gen, |&(nodes, a, size)| {
        let (nodes, a) = (nodes as u32, a as u32);
        let sched =
            collective::hierarchical_allreduce(nodes, a, size).map_err(|e| e.to_string())?;
        sched.check()?;
        let intra_expect = if a >= 2 {
            2.0 * (a as f64 - 1.0) / a as f64 * size as f64
        } else {
            0.0
        };
        let inter_expect =
            2.0 * (nodes as f64 - 1.0) / nodes as f64 * (size as f64 / a as f64);
        let slack = 4 * (nodes + a) as u64;
        for r in 0..nodes * a {
            let intra = intra_bytes(&sched, r, a);
            let inter = sched.sent_bytes(r) - intra;
            close(intra, intra_expect, slack).map_err(|e| format!("rank {r} intra: {e}"))?;
            close(inter, inter_expect, slack).map_err(|e| format!("rank {r} inter: {e}"))?;
        }
        Ok(())
    });
}

/// Bytes rank sends to peers on its own node.
fn intra_bytes(sched: &collective::Schedule, rank: u32, accels_per_node: u32) -> u64 {
    sched.steps[rank as usize]
        .iter()
        .map(|s| match s {
            Step::Send { peer, size_b } if peer / accels_per_node == rank / accels_per_node => {
                *size_b as u64
            }
            _ => 0,
        })
        .sum()
}

#[test]
fn prop_build_is_deadlock_free_for_every_op_and_layout() {
    let gen = Triple(
        Choice(&CollOp::ALL),
        Pair(IntRange { lo: 2, hi: 8 }, IntRange { lo: 1, hi: 8 }),
        IntRange { lo: 1, hi: 1 << 20 },
    );
    forall(0xDEAD, 120, &gen, |&(op, (nodes, accels), size)| {
        let (nodes, accels) = (nodes as u32, accels as u32);
        // Derive a NIC count from the size so the hierarchical leader
        // election is exercised across 1..=8 NICs too.
        let nics = 1 + (size % 8) as u32;
        let spec =
            CollectiveSpec { op, scope: CollScope::Global, size_b: size, iters: 1 };
        let sched = collective::build(&spec, nodes, accels, nics).map_err(|e| e.to_string())?;
        sched.check()?;
        // A non-trivial system always yields a non-empty schedule.
        if sched.total_steps() == 0 {
            return Err("empty schedule".into());
        }
        Ok(())
    });
}

#[test]
fn prop_per_node_scope_never_crosses_nodes() {
    let gen = Triple(
        Choice(&[CollOp::RingAllReduce, CollOp::ReduceScatter, CollOp::AllGather, CollOp::AllToAll]),
        Pair(IntRange { lo: 2, hi: 6 }, IntRange { lo: 2, hi: 8 }),
        IntRange { lo: 1, hi: 1 << 18 },
    );
    forall(0x5C09E, 80, &gen, |&(op, (nodes, accels), size)| {
        let (nodes, accels) = (nodes as u32, accels as u32);
        let spec =
            CollectiveSpec { op, scope: CollScope::PerNode, size_b: size, iters: 1 };
        let sched = collective::build(&spec, nodes, accels, 1 + (size % 4) as u32)
            .map_err(|e| e.to_string())?;
        sched.check()?;
        for (rank, prog) in sched.steps.iter().enumerate() {
            let node = rank as u32 / accels;
            for s in prog {
                let peer = match s {
                    Step::Send { peer, .. } | Step::Recv { peer } => *peer,
                };
                if peer / accels != node {
                    return Err(format!("rank {rank} crosses nodes to {peer} ({op:?})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_multinic_hierarchical_sound_and_volume_preserving() {
    // The leader-based inter exchange must stay deadlock-free and keep
    // the same global wire volume as the per-rank schedule for every
    // (nodes, accels, nics, size) combination.
    let gen = Triple(
        Pair(IntRange { lo: 2, hi: 6 }, IntRange { lo: 2, hi: 8 }),
        IntRange { lo: 1, hi: 8 },
        IntRange { lo: 1, hi: 1 << 22 },
    );
    forall(0x141C, 80, &gen, |&((nodes, a), nics, size)| {
        let (nodes, a, nics) = (nodes as u32, a as u32, nics as u32);
        let sched = collective::hierarchical_allreduce_multinic(nodes, a, nics, size)
            .map_err(|e| e.to_string())?;
        sched.check()?;
        let legacy = collective::hierarchical_allreduce(nodes, a, size).map_err(|e| e.to_string())?;
        let inter = |s: &collective::Schedule| -> u64 {
            (0..nodes * a).map(|r| s.sent_bytes(r) - intra_bytes(s, r, a)).sum()
        };
        let (iv, lv) = (inter(&sched), inter(&legacy));
        // Same reduced bytes cross the node boundary either way (slack:
        // 1-byte control bumps on empty shards + shard rounding).
        let slack = (4 * (nodes + a) * nics) as u64;
        if iv.abs_diff(lv) > slack {
            return Err(format!("inter volume {iv} (leaders) vs {lv} (per-rank)"));
        }
        Ok(())
    });
}

#[test]
fn prop_hierarchical_sound_over_size_batches() {
    // VecGen drives a whole batch of sizes per case; on failure the vector
    // shrinks to the minimal offending size set.
    let gen = VecGen { elem: IntRange { lo: 1, hi: 1 << 22 }, min_len: 1, max_len: 6 };
    forall(0xBA7C4, 40, &gen, |sizes| {
        for &size in sizes {
            let sched = collective::hierarchical_allreduce(4, 8, size)
                .map_err(|e| format!("size {size}: {e}"))?;
            sched.check().map_err(|e| format!("size {size}: {e}"))?;
            let sent: u64 = (0..32).map(|r| sched.sent_bytes(r)).sum();
            let recv: u64 = (0..32).map(|r| sched.recv_bytes(r)).sum();
            if sent != recv {
                return Err(format!("size {size}: sent {sent} != recv {recv}"));
            }
        }
        Ok(())
    });
}
