//! Reuse equivalence suite: a blueprint-instantiated, reset-reused
//! `World` must produce a **bit-identical** [`SimReport`] (every field
//! except `wall_ms` — including the dispatched-event count, since both
//! engines run the same event sequence) to a freshly constructed one.
//!
//! The property is the correctness anchor of the compile-once /
//! reset-reuse split (`net::world::WorldBlueprint`): it is exercised
//! across all four intra fabrics, both NIC policies and multi-NIC
//! counts, and every workload kind (open loop, PingPong, Window, all
//! collective ops). A materiality check asserts the reuse is real —
//! slab capacities and high-water marks stay stable across reused
//! points instead of being reallocated.

use std::sync::Arc;

use sauron::config::{
    presets, CollOp, CollScope, CollectiveSpec, FabricConfig, FabricKind, NicPolicy, Pattern,
    SimConfig, Workload,
};
use sauron::net::world::{BenchMode, NativeProvider, Sim, SimReport, WorldBlueprint};
use sauron::testkit::{forall, Choice, FloatRange, Triple};

/// Compare every result-describing field; only `wall_ms` is excluded.
fn reports_identical(reused: &SimReport, fresh: &SimReport) -> Result<(), String> {
    macro_rules! field_eq {
        ($field:ident) => {
            if reused.$field != fresh.$field {
                return Err(format!(
                    "field {} differs: {:?} (reused) vs {:?} (fresh)",
                    stringify!($field),
                    reused.$field,
                    fresh.$field
                ));
            }
        };
    }
    field_eq!(pattern);
    field_eq!(load);
    field_eq!(nodes);
    field_eq!(accels);
    field_eq!(fabric);
    field_eq!(nics);
    field_eq!(inter);
    field_eq!(aggregated_intra_gbs);
    field_eq!(offered_gbs);
    field_eq!(intra_tput_gbs);
    field_eq!(intra_drain_gbs);
    field_eq!(intra_lat);
    field_eq!(inter_tput_gbs);
    field_eq!(inter_drain_gbs);
    field_eq!(fct);
    field_eq!(intra_wire_gbs);
    field_eq!(inter_wire_gbs);
    field_eq!(drop_frac);
    field_eq!(delivered_msgs);
    field_eq!(offered_msgs);
    field_eq!(events);
    field_eq!(table_misses);
    field_eq!(coll_op);
    field_eq!(coll_size_b);
    field_eq!(coll_iters);
    field_eq!(coll_time);
    field_eq!(coll_pred_ns);
    Ok(())
}

/// Dirty a blueprint-pinned sim on `first`, reset it to `second`, and
/// compare the reused run against a from-scratch build of `second`.
fn check_reuse(first: SimConfig, second: SimConfig) -> Result<(), String> {
    let bp = Arc::new(
        WorldBlueprint::compile(first.clone(), &NativeProvider, BenchMode::None, &[])
            .map_err(|e| format!("compile: {e:#}"))?,
    );
    let mut sim =
        Sim::from_blueprint(&bp, first).map_err(|e| format!("instantiate: {e:#}"))?;
    sim.try_run_mut().map_err(|e| format!("first run: {e:#}"))?;
    sim.reset(second.clone()).map_err(|e| format!("reset: {e:#}"))?;
    let reused = sim.try_run_mut().map_err(|e| format!("reused run: {e:#}"))?;
    let fresh = Sim::new(second, &NativeProvider, BenchMode::None)
        .map_err(|e| format!("fresh build: {e:#}"))?
        .try_run()
        .map_err(|e| format!("fresh run: {e:#}"))?;
    reports_identical(&reused, &fresh)
}

fn fabric_cfg(
    kind: FabricKind,
    nics: usize,
    policy: NicPolicy,
    load: f64,
    pattern: Pattern,
    seed: u64,
) -> SimConfig {
    let mut fab = FabricConfig::new(kind, nics);
    fab.nic_policy = policy;
    let mut cfg = presets::with_fabric(presets::scaleout(32, 256.0, pattern, load), fab);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    cfg.seed = seed;
    cfg
}

#[test]
fn prop_open_loop_reuse_identical_across_fabrics_and_policies() {
    // Load capped below saturation: at sustained overload the ring
    // fabric can hit its (diagnosed) credit-cycle deadlock, which is a
    // legitimate outcome but not a report to compare.
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[
            (1usize, NicPolicy::LocalRank),
            (2, NicPolicy::LocalRank),
            (2, NicPolicy::RoundRobin),
            (4, NicPolicy::RoundRobin),
        ]),
        FloatRange { lo: 0.05, hi: 0.45 },
    );
    forall(0x2E05E, 10, &gen, |&(kind, (nics, policy), load)| {
        // The dirtying point and the measured point differ in load,
        // pattern and seed — all run-phase deltas of one blueprint.
        let first = fabric_cfg(kind, nics, policy, (load * 0.5).max(0.05), Pattern::C1, 7);
        let second = fabric_cfg(kind, nics, policy, load, Pattern::C3, 0xD15EA5E);
        check_reuse(first, second)
            .map_err(|e| format!("{kind:?}/{nics}nic/{policy:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_collective_reuse_identical_with_iters_delta() {
    let gen = Triple(
        Choice(&[
            CollOp::RingAllReduce,
            CollOp::ReduceScatter,
            CollOp::AllGather,
            CollOp::AllToAll,
        ]),
        Choice(&[16u64 * 1024, 64 * 1024]),
        Choice(&[0.0f64, 0.3]),
    );
    forall(0x2E05C, 8, &gen, |&(op, size_b, bg_load)| {
        let base = |iters: u32, seed: u64| {
            let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, bg_load);
            cfg.warmup_us = 5.0;
            cfg.measure_us = 10.0;
            cfg.seed = seed;
            cfg.workload = Workload::Collective(CollectiveSpec {
                op,
                scope: CollScope::PerNode,
                size_b,
                iters,
            });
            cfg
        };
        // `iters` is the one workload knob that is a run-phase delta.
        check_reuse(base(2, 11), base(3, 0xBEEF))
            .map_err(|e| format!("{op:?}/{size_b}/{bg_load}: {e}"))
    });
}

#[test]
fn prop_inter_kind_reuse_identical() {
    // Per-inter-kind equivalence: each pluggable inter topology is its
    // own blueprint; a reset-reused world on it must stay bit-identical
    // to a fresh build of the same point (leaf_spine doubling as the
    // bit-for-bit default-config anchor).
    let gen = Triple(
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        Choice(&[Pattern::C1, Pattern::C3]),
        FloatRange { lo: 0.05, hi: 0.45 },
    );
    forall(0x2E05F, 9, &gen, |&(inter, pattern, load)| {
        let cfg = |seed: u64, load: f64, pattern: Pattern| {
            let mut cfg = presets::scaleout(32, 256.0, pattern, load);
            cfg.inter.kind =
                presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
            cfg.warmup_us = 5.0;
            cfg.measure_us = 10.0;
            cfg.seed = seed;
            cfg
        };
        check_reuse(cfg(7, (load * 0.5).max(0.05), Pattern::C1), cfg(0xD15EA5E, load, pattern))
            .map_err(|e| format!("{inter}/{pattern:?}/{load:.3}: {e}"))
    });
}

#[test]
fn hierarchical_reuse_identical_on_fat_tree_and_dragonfly() {
    // The paper's interference scenario on the multi-level topologies:
    // a reused world crossing agg/core (or local/global) trunks must
    // still be indistinguishable from fresh builds.
    for inter in ["fat_tree3", "dragonfly"] {
        let cfg = |seed: u64, bg_load: f64| {
            let mut cfg =
                presets::scaleout(32, 256.0, Pattern::Custom { frac_inter: 1.0 }, bg_load);
            cfg.inter.kind =
                presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
            cfg.warmup_us = 5.0;
            cfg.measure_us = 20.0;
            cfg.seed = seed;
            cfg.workload = Workload::Collective(CollectiveSpec {
                op: CollOp::HierarchicalAllReduce,
                scope: CollScope::Global,
                size_b: 128 * 1024,
                iters: 2,
            });
            cfg
        };
        check_reuse(cfg(1, 0.1), cfg(99, 0.2)).unwrap_or_else(|e| panic!("{inter}: {e}"));
    }
}

#[test]
fn hierarchical_multinic_reuse_identical() {
    // The paper's interference scenario: global two-level AllReduce over
    // all-inter background traffic, leader-based inter exchange on 2
    // NICs. The reused world must reproduce it bit-for-bit.
    let cfg = |seed: u64, bg_load: f64| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::Custom { frac_inter: 1.0 }, bg_load);
        cfg = presets::with_fabric(cfg, FabricConfig::new(FabricKind::SwitchStar, 2));
        cfg.warmup_us = 5.0;
        cfg.measure_us = 20.0;
        cfg.seed = seed;
        cfg.workload = Workload::Collective(CollectiveSpec {
            op: CollOp::HierarchicalAllReduce,
            scope: CollScope::Global,
            size_b: 256 * 1024,
            iters: 2,
        });
        cfg
    };
    check_reuse(cfg(1, 0.1), cfg(99, 0.2)).unwrap();
}

#[test]
fn bench_driver_reuse_identical() {
    // PingPong and Window are explicit-bench workloads: the bench (and
    // its table-priming sizes) is pinned by the blueprint, the per-point
    // config varies seed and windows.
    for (bench, sizes) in [
        // Inter-node endpoints so the FCT sanity check below sees traffic.
        (BenchMode::PingPong { a: 0, b: 17, size_b: 4096 }, vec![4096u32]),
        (BenchMode::Window { src: 0, dst: 9, size_b: 1 << 16, inflight: 4 }, vec![1 << 16]),
    ] {
        let cfg = |seed: u64, measure_us: f64| {
            let mut cfg = presets::scaleout(32, 256.0, Pattern::C5, 0.0);
            cfg.warmup_us = 5.0;
            cfg.measure_us = measure_us;
            cfg.seed = seed;
            cfg
        };
        let bp = Arc::new(
            WorldBlueprint::compile(cfg(1, 20.0), &NativeProvider, bench, &sizes).unwrap(),
        );
        let mut sim = Sim::from_blueprint(&bp, cfg(1, 20.0)).unwrap();
        sim.try_run_mut().unwrap();
        sim.reset(cfg(2, 30.0)).unwrap();
        let reused = sim.try_run_mut().unwrap();
        let fresh = Sim::with_extra_sizes(cfg(2, 30.0), &NativeProvider, bench, &sizes)
            .unwrap()
            .try_run()
            .unwrap();
        reports_identical(&reused, &fresh).unwrap_or_else(|e| panic!("{bench:?}: {e}"));
        assert!(reused.fct.count > 0, "{bench:?}: sanity — traffic flowed");
    }
}

#[test]
fn reuse_is_material_allocations_and_high_water_stay_stable() {
    // Re-running the same point through reset must reuse the first run's
    // allocations: slab backing capacity unchanged (nothing reallocated)
    // and slot high-water marks identical (same simulated work).
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, 0.6);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    let bp = Arc::new(
        WorldBlueprint::compile(cfg.clone(), &NativeProvider, BenchMode::None, &[]).unwrap(),
    );
    let mut sim = Sim::from_blueprint(&bp, cfg.clone()).unwrap();
    let first = sim.try_run_mut().unwrap();
    let caps = sim.world().slab_capacities();
    let slots = sim.world().slab_slots();
    assert!(slots.0 > 0 && slots.1 > 0, "sanity: the run used the slabs");
    for round in 0..3 {
        sim.reset(cfg.clone()).unwrap();
        let again = sim.try_run_mut().unwrap();
        reports_identical(&again, &first).unwrap_or_else(|e| panic!("round {round}: {e}"));
        assert_eq!(
            sim.world().slab_capacities(),
            caps,
            "round {round}: reset reallocated slab storage"
        );
        assert_eq!(
            sim.world().slab_slots(),
            slots,
            "round {round}: high-water marks moved on an identical point"
        );
    }
}

#[test]
fn blueprint_is_shareable_across_threads() {
    // The sweep path hands one Arc'd blueprint to every worker; two
    // threads instantiating and running different points concurrently
    // must each match their fresh builds.
    let point = |load: f64, seed: u64| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, load);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        cfg.seed = seed;
        cfg
    };
    let bp = Arc::new(
        WorldBlueprint::compile(point(0.2, 1), &NativeProvider, BenchMode::None, &[]).unwrap(),
    );
    let handles: Vec<_> = [(0.2f64, 1u64), (0.4, 2), (0.6, 3), (0.8, 4)]
        .into_iter()
        .map(|(load, seed)| {
            let bp = bp.clone();
            std::thread::spawn(move || {
                let mut sim = Sim::from_blueprint(&bp, point(load, seed)).unwrap();
                let reused = sim.try_run_mut().unwrap();
                let fresh =
                    Sim::new(point(load, seed), &NativeProvider, BenchMode::None).unwrap().run();
                reports_identical(&reused, &fresh).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().expect("worker panicked");
    }
}
