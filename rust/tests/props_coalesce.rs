//! Equivalence suite: the event-coalesced engine (transaction trains on
//! delivery links, `SimConfig::coalescing = true`) must be
//! indistinguishable from the scalar one-event-per-unit engine.
//! Coalescing changes how many heap events the DES dispatches — never
//! what happens at any simulated instant — so every [`SimReport`] field
//! except the dispatched-event count and wall-clock time must match
//! bit-for-bit, across open-loop traffic, bench drivers and collective
//! workloads.

use sauron::config::{
    presets, CollOp, CollScope, CollectiveSpec, Pattern, SimConfig, Workload,
};
use sauron::net::world::{BenchMode, NativeProvider, Sim, SimReport};
use sauron::testkit::{forall, Choice, FloatRange, Triple};

fn run_engine(cfg: &SimConfig, coalescing: bool, bench: BenchMode, sizes: &[u32]) -> SimReport {
    let mut cfg = cfg.clone();
    cfg.coalescing = coalescing;
    Sim::with_extra_sizes(cfg, &NativeProvider, bench, sizes).expect("valid config").run()
}

/// Compare every field that describes simulation *results*. `events`
/// (dispatching fewer is coalescing's whole point) and `wall_ms` are
/// excluded by construction.
fn reports_identical(a: &SimReport, b: &SimReport) -> Result<(), String> {
    macro_rules! field_eq {
        ($field:ident) => {
            if a.$field != b.$field {
                return Err(format!(
                    "field {} differs: {:?} (coalesced) vs {:?} (scalar)",
                    stringify!($field),
                    a.$field,
                    b.$field
                ));
            }
        };
    }
    field_eq!(pattern);
    field_eq!(load);
    field_eq!(nodes);
    field_eq!(accels);
    field_eq!(fabric);
    field_eq!(nics);
    field_eq!(inter);
    field_eq!(aggregated_intra_gbs);
    field_eq!(offered_gbs);
    field_eq!(intra_tput_gbs);
    field_eq!(intra_drain_gbs);
    field_eq!(intra_lat);
    field_eq!(inter_tput_gbs);
    field_eq!(inter_drain_gbs);
    field_eq!(fct);
    field_eq!(intra_wire_gbs);
    field_eq!(inter_wire_gbs);
    field_eq!(drop_frac);
    field_eq!(delivered_msgs);
    field_eq!(offered_msgs);
    field_eq!(table_misses);
    field_eq!(dropped_units);
    field_eq!(coll_op);
    field_eq!(coll_size_b);
    field_eq!(coll_iters);
    field_eq!(coll_time);
    field_eq!(coll_pred_ns);
    Ok(())
}

#[test]
fn prop_open_loop_reports_identical() {
    // Light load through full saturation (deep queues exercise long
    // trains, parked-waiter truncation and the stale-event path).
    let gen = Triple(
        Choice(&[128.0f64, 256.0, 512.0]),
        Choice(&[Pattern::C1, Pattern::C3, Pattern::C5]),
        FloatRange { lo: 0.05, hi: 1.0 },
    );
    forall(0xC0A1, 10, &gen, |&(gbs, pattern, load)| {
        let mut cfg = presets::scaleout(32, gbs, pattern, load);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).map_err(|e| format!("{gbs}/{pattern:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_collective_reports_identical() {
    // Per-node collectives with and without Poisson background traffic.
    let gen = Triple(
        Choice(&[
            CollOp::RingAllReduce,
            CollOp::ReduceScatter,
            CollOp::AllGather,
            CollOp::AllToAll,
        ]),
        Choice(&[16u64 * 1024, 64 * 1024, 96 * 1024]),
        Choice(&[0.0f64, 0.3]),
    );
    forall(0xC0A2, 8, &gen, |&(op, size_b, bg_load)| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, bg_load);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        cfg.workload = Workload::Collective(CollectiveSpec {
            op,
            scope: CollScope::PerNode,
            size_b,
            iters: 2,
        });
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).map_err(|e| format!("{op:?}/{size_b}/{bg_load}: {e}"))
    });
}

#[test]
fn hierarchical_collective_reports_identical() {
    // Global two-level AllReduce over inter-node background traffic —
    // the paper's interference scenario, closed loop and congested.
    let mut cfg = presets::scaleout(32, 256.0, Pattern::Custom { frac_inter: 1.0 }, 0.2);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 20.0;
    cfg.workload = Workload::Collective(CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 2,
    });
    let fast = run_engine(&cfg, true, BenchMode::None, &[]);
    let slow = run_engine(&cfg, false, BenchMode::None, &[]);
    reports_identical(&fast, &slow).unwrap();
    assert_eq!(fast.coll_iters, 2);
}

#[test]
fn window_bench_reports_identical() {
    // 1 MiB messages segment into ~260 MTU transactions: the delivery
    // link runs long trains that end exactly at each message-completing
    // unit (Window re-injection is feedback).
    let mut cfg = presets::cellia();
    cfg.warmup_us = 10.0;
    cfg.measure_us = 50.0;
    let bench = BenchMode::Window { src: 0, dst: 1, size_b: 1 << 20, inflight: 4 };
    let fast = run_engine(&cfg, true, bench, &[1 << 20]);
    let slow = run_engine(&cfg, false, bench, &[1 << 20]);
    reports_identical(&fast, &slow).unwrap();
    assert!(fast.inter_drain_gbs > 10.0, "sanity: EDR window stays saturated");
}

#[test]
fn pingpong_bench_reports_identical() {
    // CELLIA round trips: every completion re-injects, so each train ends
    // at a feedback unit and the bounce-back timing must stay exact.
    let mut cfg = presets::cellia();
    cfg.warmup_us = 5.0;
    cfg.measure_us = 50.0;
    let bench = BenchMode::PingPong { a: 0, b: 1, size_b: 4096 };
    let fast = run_engine(&cfg, true, bench, &[4096]);
    let slow = run_engine(&cfg, false, bench, &[4096]);
    reports_identical(&fast, &slow).unwrap();
    assert!(fast.fct.count > 10, "sanity: round trips happened");
}

#[test]
fn prop_fabric_reports_identical() {
    // The non-star fabrics mix delivering and forwarding units on the
    // same link (a mesh lane serves both deliveries and the egress leg
    // to a NIC host), so the delivery-train prefix logic gets exercised
    // beyond what the star can reach. Equivalence must hold regardless.
    use sauron::config::{FabricConfig, FabricKind};
    // Load capped below saturation: at sustained overload the ring
    // fabric can hit its (diagnosed) credit-cycle deadlock, which is a
    // legitimate outcome but not a report to compare.
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[1usize, 2, 4]),
        FloatRange { lo: 0.05, hi: 0.45 },
    );
    forall(0xFAB5, 10, &gen, |&(kind, nics, load)| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, load);
        cfg = presets::with_fabric(cfg, FabricConfig::new(kind, nics));
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).map_err(|e| format!("{kind:?}/{nics}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_inter_kind_reports_identical() {
    // Coalescing equivalence across the pluggable inter topologies: the
    // multi-level trunks (agg/core up/down, dragonfly local/global) run
    // forwarding-hop trains the 2-level leaf/spine never builds, and
    // the leaf_spine case anchors the bit-for-bit default.
    let gen = Triple(
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        Choice(&[Pattern::C1, Pattern::C2]),
        FloatRange { lo: 0.05, hi: 0.45 },
    );
    forall(0xC0A3, 9, &gen, |&(inter, pattern, load)| {
        let mut cfg = presets::scaleout(32, 256.0, pattern, load);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).map_err(|e| format!("{inter}/{pattern:?}/{load:.3}: {e}"))
    });
}

#[test]
fn hierarchical_reports_identical_on_fat_tree_and_dragonfly() {
    // The interference scenario on the multi-level topologies.
    for inter in ["fat_tree3", "dragonfly"] {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::Custom { frac_inter: 1.0 }, 0.2);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 15.0;
        cfg.workload = Workload::Collective(CollectiveSpec {
            op: CollOp::HierarchicalAllReduce,
            scope: CollScope::Global,
            size_b: 128 * 1024,
            iters: 2,
        });
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).unwrap_or_else(|e| panic!("{inter}: {e}"));
        assert_eq!(fast.inter, inter);
    }
}

#[test]
fn multinic_hierarchical_reports_identical() {
    // Leader-based inter exchange over 2 NICs against background
    // traffic: the multi-rail hot path must coalesce identically.
    use sauron::config::{FabricConfig, FabricKind};
    let mut cfg = presets::scaleout(32, 256.0, Pattern::Custom { frac_inter: 1.0 }, 0.2);
    cfg = presets::with_fabric(cfg, FabricConfig::new(FabricKind::SwitchStar, 2));
    cfg.warmup_us = 5.0;
    cfg.measure_us = 20.0;
    cfg.workload = Workload::Collective(CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 2,
    });
    let fast = run_engine(&cfg, true, BenchMode::None, &[]);
    let slow = run_engine(&cfg, false, BenchMode::None, &[]);
    reports_identical(&fast, &slow).unwrap();
    assert_eq!(fast.coll_iters, 2);
    assert_eq!(fast.nics, 2);
}

#[test]
fn prop_interior_trains_at_high_load_reports_identical() {
    // Forwarding-hop (interior) trains: at high inter-heavy load the
    // SwToNic/NicUp segments and the multi-level trunks queue same-next-
    // hop runs that coalesce into cascades whose boundaries commit the
    // downstream reservation lazily. Saturation is exactly where the
    // abort-on-no-room path must replay the scalar park bit-for-bit.
    let gen = Triple(
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        Choice(&[Pattern::C1, Pattern::Custom { frac_inter: 0.6 }]),
        FloatRange { lo: 0.5, hi: 1.0 },
    );
    forall(0xC0A4, 9, &gen, |&(inter, pattern, load)| {
        let mut cfg = presets::scaleout(32, 256.0, pattern, load);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).map_err(|e| format!("{inter}/{pattern:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_interior_trains_across_fabrics_reports_identical() {
    // Fabric × inter cross at loads past the old 0.45 cap (the ring
    // fabric is excluded: sustained overload can hit its diagnosed
    // credit-cycle deadlock, a legitimate outcome but not a report).
    use sauron::config::{FabricConfig, FabricKind};
    let gen = Triple(
        Choice(&[FabricKind::SwitchStar, FabricKind::Mesh, FabricKind::HostTree]),
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        FloatRange { lo: 0.5, hi: 0.85 },
    );
    forall(0xC0A5, 9, &gen, |&(kind, inter, load)| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, load);
        cfg = presets::with_fabric(cfg, FabricConfig::new(kind, 2));
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).map_err(|e| format!("{kind:?}/{inter}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_fault_segment_boundary_mid_train_reports_identical() {
    // A firing fault plan lands inside the measure window while interior
    // trains are running: construction caps every boundary at the fault
    // instant and `apply_due_faults` settles all cascades first, so the
    // degrade → kill → recover cycle must leave coalesced and scalar
    // runs identical in everything but the dispatched-event count.
    use sauron::config::{FaultAction, FaultEvent, FaultPlan, LinkSel};
    let gen = Triple(
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        Choice(&[0.5f64, 0.25]),
        FloatRange { lo: 0.4, hi: 0.8 },
    );
    forall(0xC0A6, 9, &gen, |&(inter, factor, load)| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, load);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        let sel = LinkSel::NicUp { node: 0, nic: 0 };
        cfg.faults = FaultPlan {
            events: vec![
                FaultEvent {
                    at_us: 7.0,
                    action: FaultAction::LinkDegrade { factor },
                    sel: Some(sel),
                },
                FaultEvent { at_us: 9.0, action: FaultAction::LinkDown, sel: Some(sel) },
                FaultEvent { at_us: 12.0, action: FaultAction::Recover, sel: Some(sel) },
            ],
        };
        let fast = run_engine(&cfg, true, BenchMode::None, &[]);
        let slow = run_engine(&cfg, false, BenchMode::None, &[]);
        reports_identical(&fast, &slow).map_err(|e| format!("{inter}/{factor}/{load:.3}: {e}"))
    });
}

#[test]
fn interior_trains_reduce_dispatched_events_on_inter_paths() {
    // The tentpole's perf claim, observable without a profiler: with
    // all-inter traffic the hop sequence runs through SwToNic → NicUp →
    // trunks, and interior cascades must materially cut heap events
    // versus scalar stepping (delivery-only trains barely touch this
    // traffic mix).
    let mut cfg = presets::scaleout(32, 256.0, Pattern::Custom { frac_inter: 1.0 }, 0.7);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 20.0;
    let fast = run_engine(&cfg, true, BenchMode::None, &[]);
    let slow = run_engine(&cfg, false, BenchMode::None, &[]);
    reports_identical(&fast, &slow).unwrap();
    assert!(
        (fast.events as f64) < 0.95 * slow.events as f64,
        "expected a real event reduction on inter paths: {} coalesced vs {} scalar",
        fast.events,
        slow.events
    );
}

#[test]
fn coalesced_engine_is_deterministic() {
    let run = || {
        let mut cfg = presets::scaleout(32, 512.0, Pattern::C1, 0.9);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        run_engine(&cfg, true, BenchMode::None, &[])
    };
    let a = run();
    let b = run();
    assert_eq!(a.events, b.events);
    assert_eq!(a.delivered_msgs, b.delivered_msgs);
    reports_identical(&a, &b).unwrap();
}

#[test]
fn coalescing_reduces_dispatched_events_at_high_load() {
    // Not just "no different": at high-but-unsaturated intra load the
    // delivery queues run transient bursts that batch into trains, which
    // must show up as materially fewer heap events. (At full saturation
    // parked waiters force per-unit pacing, so the win lives below the
    // knee — exactly where sweeps spend their time.)
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C5, 0.7);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 20.0;
    let fast = run_engine(&cfg, true, BenchMode::None, &[]);
    let slow = run_engine(&cfg, false, BenchMode::None, &[]);
    reports_identical(&fast, &slow).unwrap();
    assert!(
        (fast.events as f64) < 0.95 * slow.events as f64,
        "expected a real event reduction: {} coalesced vs {} scalar",
        fast.events,
        slow.events
    );
}
