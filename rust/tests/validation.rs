//! Validation vs the paper's published CELLIA measurements (Tables 1/2,
//! Figure 4). We do not have the cluster; the paper's numbers are the
//! ground truth (DESIGN.md substitution table). Tolerances are loose
//! enough for a packet-level model, tight enough to catch regressions in
//! the PCIe/NIC/IB calibration.

use sauron::config::{presets, FabricConfig, FabricKind, Pattern};
use sauron::net::world::NativeProvider;
use sauron::traffic::ib_bench::{self, TEST_SIZES};
use sauron::units::{KIB, MIB};

/// Table 2 latency within 30% of the paper across the size sweep, and
/// within 10% for the large (>= 128 KiB) rows where pipeline behaviour
/// dominates calibration constants.
#[test]
fn table2_latency_tracks_paper() {
    for &size in &[128, 4 * KIB, 128 * KIB, MIB, 4 * MIB] {
        let p = ib_bench::latency_test(&NativeProvider, size).unwrap();
        let rel = (p.sim_us - p.paper_us).abs() / p.paper_us;
        let tol = if size >= 128 * KIB { 0.10 } else { 0.30 };
        assert!(rel < tol, "{size} B: sim {:.2} vs paper {:.2} ({rel:.2})", p.sim_us, p.paper_us);
    }
}

/// Table 1 bandwidth within 25% everywhere, 10% for the calibrated ends.
#[test]
fn table1_bandwidth_tracks_paper() {
    for &size in &[128, 512, 4 * KIB, 64 * KIB, MIB] {
        let p = ib_bench::bandwidth_test(&NativeProvider, size).unwrap();
        let rel = (p.sim_gib_s - p.paper_gib_s).abs() / p.paper_gib_s;
        let tol = if size == 128 || size >= 64 * KIB { 0.10 } else { 0.25 };
        assert!(
            rel < tol,
            "{size} B: sim {:.2} vs paper {:.2} GiB/s ({rel:.2})",
            p.sim_gib_s,
            p.paper_gib_s
        );
    }
}

/// Figure 4a shape: bandwidth rises monotonically with message size and
/// saturates near the EDR payload bound.
#[test]
fn fig4_bandwidth_monotone_to_saturation() {
    let sizes = [128u64, 1 * KIB, 4 * KIB, 32 * KIB, 256 * KIB, 2 * MIB];
    let mut last = 0.0;
    for &s in &sizes {
        let bw = ib_bench::bandwidth_test(&NativeProvider, s).unwrap().sim_gib_s;
        assert!(bw >= last * 0.98, "bandwidth dipped at {s}: {bw} after {last}");
        last = bw;
    }
    assert!(last > 11.0 && last < 12.0, "saturation {last}");
}

/// Figure 4b shape: latency is flat for sub-MTU messages, then linear in
/// size (slope ~ 1/12.3 GB/s).
#[test]
fn fig4_latency_flat_then_linear() {
    let small = ib_bench::latency_test(&NativeProvider, 128).unwrap().sim_us;
    let mtu = ib_bench::latency_test(&NativeProvider, 4 * KIB).unwrap().sim_us;
    assert!(mtu < 3.5 * small, "no cliff below MTU: {small} -> {mtu}");
    let m1 = ib_bench::latency_test(&NativeProvider, MIB).unwrap().sim_us;
    let m4 = ib_bench::latency_test(&NativeProvider, 4 * MIB).unwrap().sim_us;
    let slope = (m4 - m1) / 3.0; // us per MiB
    let expect = (MIB as f64) / 12.3e3; // us per MiB at 12.3 GB/s
    assert!((slope - expect).abs() / expect < 0.1, "slope {slope:.1} vs {expect:.1} us/MiB");
}

/// Regression: Ring/Mesh fabrics with `accels_per_node == 1` have an
/// `intra_stride` of 0, so every node's link-id range would alias its
/// neighbour's. `validate()` must reject the layout with an actionable
/// error instead of building an aliased world; the single-accelerator
/// fabrics (SwitchStar, HostTree without the CPU bounce) stay legal.
#[test]
fn degenerate_single_accel_ring_and_mesh_are_rejected() {
    for kind in [FabricKind::Ring, FabricKind::Mesh] {
        let mut cfg = presets::with_fabric(
            presets::scaleout(8, 128.0, Pattern::C1, 0.2),
            FabricConfig::new(kind, 1),
        );
        cfg.node.accels_per_node = 1;
        let err = cfg.validate().expect_err("degenerate layout must be rejected");
        assert!(
            err.contains("accels_per_node == 1") && err.contains("switch_star"),
            "{kind:?}: error must name the cause and a fix, got: {err}"
        );
    }
    for kind in [FabricKind::SwitchStar, FabricKind::HostTree] {
        let mut cfg = presets::with_fabric(
            presets::scaleout(8, 128.0, Pattern::C1, 0.2),
            FabricConfig::new(kind, 1),
        );
        cfg.node.accels_per_node = 1;
        if kind == FabricKind::HostTree {
            cfg.node.rc_cpu_bounce = false;
        }
        cfg.validate().unwrap_or_else(|e| panic!("{kind:?} with one accel must stay legal: {e}"));
    }
}

/// The geomean error across the FULL 16-size sweep stays under 15% for
/// both tables (regression guard for the calibration constants).
#[test]
fn full_sweep_geomean_error_bounded() {
    let mut bw_pairs = Vec::new();
    let mut lat_pairs = Vec::new();
    for &s in TEST_SIZES.iter() {
        let b = ib_bench::bandwidth_test(&NativeProvider, s).unwrap();
        bw_pairs.push((b.sim_gib_s, b.paper_gib_s));
        let l = ib_bench::latency_test(&NativeProvider, s).unwrap();
        lat_pairs.push((l.sim_us, l.paper_us));
    }
    let bw_err = sauron::report::tables::geomean_abs_rel_err(&bw_pairs);
    let lat_err = sauron::report::tables::geomean_abs_rel_err(&lat_pairs);
    assert!(bw_err < 0.15, "Table 1 geomean error {bw_err:.3}");
    assert!(lat_err < 0.15, "Table 2 geomean error {lat_err:.3}");
}
