//! Integration tests: the paper's qualitative results (§4.2.3) must hold
//! in scaled-down sweeps (DESIGN.md "expected shapes" 1-5).

use sauron::config::{presets, Pattern, SimConfig};
use sauron::net::world::{BenchMode, NativeProvider, Sim};

fn run(nodes: usize, gbs: f64, pattern: Pattern, load: f64) -> sauron::SimReport {
    let mut cfg = presets::scaleout(nodes, gbs, pattern, load);
    cfg.warmup_us = 30.0;
    cfg.measure_us = 20.0;
    Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run()
}

/// Shape 1: saturation arrives earlier for higher inter fractions and for
/// larger intra bandwidth. C1 @ 512 GB/s must have collapsed at 60% load
/// (NIC oversubscription 819 Gbps offered vs 400 Gbps) while C5 @ 512 has
/// not.
#[test]
fn c1_at_512_saturates_before_c5() {
    let c1 = run(32, 512.0, Pattern::C1, 0.6);
    let c5 = run(32, 512.0, Pattern::C5, 0.6);
    assert!(
        c1.intra_tput_gbs < 0.75 * c5.intra_tput_gbs,
        "C1 intra {:.0} should collapse vs C5 {:.0}",
        c1.intra_tput_gbs,
        c5.intra_tput_gbs
    );
    assert!(c1.drop_frac > 0.0, "C1 must be dropping at 60% load on 512 GB/s");
}

/// Shape 2: C5 (100% intra) benefits monotonically from intra bandwidth.
#[test]
fn c5_scales_with_intra_bandwidth() {
    let a = run(32, 128.0, Pattern::C5, 0.5);
    let b = run(32, 256.0, Pattern::C5, 0.5);
    let c = run(32, 512.0, Pattern::C5, 0.5);
    assert!(b.intra_tput_gbs > 1.7 * a.intra_tput_gbs, "{} vs {}", b.intra_tput_gbs, a.intra_tput_gbs);
    assert!(c.intra_tput_gbs > 1.7 * b.intra_tput_gbs, "{} vs {}", c.intra_tput_gbs, b.intra_tput_gbs);
    assert_eq!(c.fct.count, 0, "C5 generates no inter traffic");
}

/// Shape 3: inter throughput orders C1 > C2 > C3 > C4 below saturation.
#[test]
fn inter_throughput_orders_by_pattern() {
    let loads = [Pattern::C1, Pattern::C2, Pattern::C3, Pattern::C4]
        .iter()
        .map(|&p| run(32, 128.0, p, 0.4).inter_tput_gbs)
        .collect::<Vec<_>>();
    for w in loads.windows(2) {
        assert!(w[0] > w[1], "inter ordering violated: {loads:?}");
    }
}

/// Shape 4: latency grows steeply approaching saturation; strict
/// throughput collapses past it (paper footnote 2).
#[test]
fn latency_blows_up_and_throughput_collapses_past_saturation() {
    let light = run(32, 512.0, Pattern::C1, 0.2);
    let heavy = run(32, 512.0, Pattern::C1, 1.0);
    assert!(
        heavy.intra_lat.mean_ns > 10.0 * light.intra_lat.mean_ns,
        "latency {:.0}ns -> {:.0}ns",
        light.intra_lat.mean_ns,
        heavy.intra_lat.mean_ns
    );
    // Strict inter throughput at 100% load is BELOW its 40%-load value.
    let mid = run(32, 512.0, Pattern::C1, 0.4);
    assert!(
        heavy.inter_tput_gbs < mid.inter_tput_gbs,
        "collapse: {:.0} at 1.0 load vs {:.0} at 0.4",
        heavy.inter_tput_gbs,
        mid.inter_tput_gbs
    );
}

/// Shape 5: 128-node results scale throughput ~4x with identical per-node
/// trends (latency unchanged).
#[test]
fn scaling_to_128_nodes_preserves_trends() {
    let small = run(32, 128.0, Pattern::C3, 0.4);
    let big = run(128, 128.0, Pattern::C3, 0.4);
    let ratio = big.intra_tput_gbs / small.intra_tput_gbs;
    assert!((3.3..4.7).contains(&ratio), "throughput scaling x{ratio:.2}");
    let lat_ratio = big.intra_lat.mean_ns / small.intra_lat.mean_ns;
    assert!((0.8..1.25).contains(&lat_ratio), "latency should not scale: x{lat_ratio:.2}");
}

/// The paper's second bottleneck: the destination NIC re-packetizes 4 KiB
/// inter packets into 128 B intra transactions, so the intra PCIe framing
/// inflates inter-arrival cost. Verify the accel-link wire rate exceeds
/// the delivered payload rate (TLP overhead visible).
#[test]
fn pcie_framing_overhead_visible_on_wire() {
    let r = run(32, 128.0, Pattern::C5, 0.5);
    // wire counts TLP overheads via serialization time, but tx_bytes count
    // payload; intra_wire is up+down so ~2x the delivered payload rate.
    assert!(r.intra_wire_gbs > 1.8 * r.intra_tput_gbs);
}

/// Config JSON round-trips through the full SimConfig surface.
#[test]
fn config_file_roundtrip_drives_run() {
    let cfg = presets::scaleout(32, 256.0, Pattern::C2, 0.3);
    let text = cfg.to_json_string();
    let back = SimConfig::from_json_str(&text).unwrap();
    assert_eq!(cfg, back);
    let dir = std::env::temp_dir().join("sauron_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    std::fs::write(&path, text).unwrap();
    let loaded = SimConfig::load(&path).unwrap();
    assert_eq!(loaded, cfg);
    std::fs::remove_dir_all(&dir).ok();
}

/// Deterministic arrivals behave like Poisson in the mean (sanity of the
/// arrival-process switch).
#[test]
fn arrival_processes_agree_on_mean_throughput() {
    let mut cfg = presets::scaleout(32, 128.0, Pattern::C5, 0.3);
    cfg.warmup_us = 20.0;
    cfg.measure_us = 20.0;
    let poisson = Sim::new(cfg.clone(), &NativeProvider, BenchMode::None).unwrap().run();
    cfg.traffic.arrival = sauron::config::Arrival::Deterministic;
    let det = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run();
    let rel = (poisson.intra_tput_gbs - det.intra_tput_gbs).abs() / det.intra_tput_gbs;
    assert!(rel < 0.1, "poisson {:.1} vs deterministic {:.1}", poisson.intra_tput_gbs, det.intra_tput_gbs);
}
