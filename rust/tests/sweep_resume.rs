//! Crash-safe sweep execution, end to end: a sweep killed mid-run is
//! resumed from its partial streamed CSV and must finish with a file
//! byte-identical to one from an uninterrupted run. This only holds
//! because every CSV column is a deterministic function of the config
//! (`wall_ms` is deliberately kept out of the CSV schema) and because
//! `CsvStream::resume` truncates the torn tail a kill can leave behind.
//!
//! Resume is also spec-checked: streamed CSVs carry the producing
//! spec's fingerprint as a stamp line, and resuming with a different
//! spec must fail loudly instead of silently interleaving two sweeps'
//! rows into one file.

use std::io::Write as _;
use std::sync::{Arc, Mutex};

use sauron::config::{FabricConfig, FaultPlan, InterKind, LimitsConfig, Pattern};
use sauron::coordinator::{self, pool::Backoff, results::CsvStream, SweepSpec};
use sauron::net::world::NativeProvider;

fn four_point_spec() -> SweepSpec {
    SweepSpec {
        nodes: 32,
        intra_gbs: vec![128.0],
        patterns: vec![Pattern::C3, Pattern::C5],
        loads: vec![0.1, 0.3],
        fabric: FabricConfig::switch_star(),
        inter: InterKind::LeafSpine,
        paper_windows: false,
        telemetry: false,
        workers: 2,
        seed: 7,
        faults: FaultPlan::default(),
        limits: LimitsConfig::default(),
        shards: 1,
    }
}

#[test]
fn killed_sweep_resumes_to_byte_identical_csv() {
    let spec = four_point_spec();
    let fp = spec.fingerprint();
    let dir = std::env::temp_dir().join("sauron_sweep_resume_it");
    std::fs::create_dir_all(&dir).unwrap();
    let reference = dir.join("reference.csv");
    let victim = dir.join("victim.csv");
    let provider = Arc::new(coordinator::snapshot_provider(&spec, &NativeProvider));

    // The reference: one uninterrupted streamed sweep.
    let stream = Arc::new(Mutex::new(CsvStream::create_stamped(&reference, &fp).unwrap()));
    let cb = stream.clone();
    let outcome = coordinator::run_sweep_resilient(
        &spec,
        provider.clone(),
        1,
        Backoff::NONE,
        0,
        Some(Box::new(move |idx, _, _, r| cb.lock().unwrap().push(idx, r))),
    )
    .unwrap();
    assert!(outcome.errors.is_empty(), "{:?}", outcome.errors);
    assert_eq!(stream.lock().unwrap().finish().unwrap(), 4);

    // The victim: "killed" after the first two points landed on disk —
    // the callback stops forwarding rows, finish() never runs, and the
    // kill tears the third row mid-write (no trailing newline).
    let stream = Arc::new(Mutex::new(CsvStream::create_stamped(&victim, &fp).unwrap()));
    let cb = stream.clone();
    coordinator::run_sweep_resilient(
        &spec,
        provider.clone(),
        1,
        Backoff::NONE,
        0,
        Some(Box::new(move |idx, _, _, r| {
            if idx < 2 {
                cb.lock().unwrap().push(idx, r);
            }
        })),
    )
    .unwrap();
    drop(stream);
    let mut f = std::fs::OpenOptions::new().append(true).open(&victim).unwrap();
    write!(f, "C3,0.3000,32,256,switch_star").unwrap(); // torn row
    drop(f);

    // Resuming with the wrong spec must be refused before any append:
    // same grid shape, different seed — the rows would differ, and the
    // pre-stamp resume happily accepted any file with a matching header.
    let mut foreign = four_point_spec();
    foreign.seed = 8;
    assert_ne!(foreign.fingerprint(), fp);
    let err = CsvStream::resume_stamped(&victim, &foreign.fingerprint()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fingerprint mismatch"), "{msg}");
    assert!(msg.contains(&fp), "names the stamped fingerprint: {msg}");

    // Resume with the right spec: trust the complete prefix, cut the
    // torn tail, re-run the rest with absolute indices, and append.
    let (stream, done) = CsvStream::resume_stamped(&victim, &fp).unwrap();
    assert_eq!(done, 2, "two complete rows survive the kill; the torn third does not");
    let stream = Arc::new(Mutex::new(stream));
    let cb = stream.clone();
    let outcome = coordinator::run_sweep_resilient(
        &spec,
        provider,
        1,
        Backoff::NONE,
        done,
        Some(Box::new(move |idx, _, _, r| cb.lock().unwrap().push(idx, r))),
    )
    .unwrap();
    assert!(outcome.errors.is_empty(), "{:?}", outcome.errors);
    assert_eq!(outcome.completed(), 2, "only the missing points re-run");
    assert_eq!(stream.lock().unwrap().finish().unwrap(), 4);

    let resumed = std::fs::read_to_string(&victim).unwrap();
    let uninterrupted = std::fs::read_to_string(&reference).unwrap();
    assert_eq!(
        resumed, uninterrupted,
        "killed-and-resumed sweep CSV must be byte-identical to an uninterrupted run's"
    );
    std::fs::remove_dir_all(&dir).ok();
}
