//! Process-level crash tests for the sweep job service: the supervisor
//! is SIGKILLed mid-grid and restarted on the same spool, a hung worker
//! loses its lease, a SIGTERM drains gracefully, and points that
//! exhaust the retry budget are quarantined as declared CSV holes. In
//! every case the surviving CSV must be byte-identical to (or a
//! declared-hole subset of) an uninterrupted run's — the acceptance
//! bar of the service's journal-replay design.
//!
//! These tests spawn the real `sauron` binary (supervisor and worker
//! processes alike), so they exercise the spool, the journals, the
//! heartbeat files and the signal handling exactly as an operator
//! would hit them.

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_sauron")
}

fn fresh_spool(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sauron_service_it_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A 12-point grid (2 intra bandwidths x 6 loads) heavy enough that the
/// supervisor can realistically be killed mid-grid.
fn grid_spec() -> &'static str {
    r#"{"nodes": 32, "intra_gbs": [128, 512], "patterns": ["C3"],
        "loads": [0.1, 0.2, 0.3, 0.4, 0.5, 0.6], "workers": 2, "seed": 7}"#
}

/// Submit `spec` (written to `<spool>/<name>.json`) and return the job id.
fn submit(spool: &Path, name: &str, spec: &str) -> String {
    let spec_path = spool.join(format!("{name}.json"));
    std::fs::write(&spec_path, spec).unwrap();
    let out = Command::new(bin())
        .arg("submit")
        .arg(&spec_path)
        .arg("--spool")
        .arg(spool)
        .output()
        .unwrap();
    assert!(out.status.success(), "submit failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    stdout
        .lines()
        .find_map(|l| l.strip_prefix("queued "))
        .and_then(|r| r.split_whitespace().next())
        .unwrap_or_else(|| panic!("no job id in submit output: {stdout}"))
        .to_string()
}

fn serve_cmd(spool: &Path, extra: &[&str]) -> Command {
    let mut cmd = Command::new(bin());
    cmd.arg("serve").arg("--spool").arg(spool).arg("--native").arg("--poll-ms").arg("10");
    for a in extra {
        cmd.arg(a);
    }
    cmd.stdin(Stdio::null()).stdout(Stdio::null()).stderr(Stdio::null());
    cmd
}

/// Run `sauron serve --once` to completion and assert it exits 0.
fn serve_once(spool: &Path, extra: &[&str]) {
    let status = serve_cmd(spool, extra).arg("--once").status().unwrap();
    assert!(status.success(), "serve --once exited with {status}");
}

fn csv_path(spool: &Path, id: &str) -> PathBuf {
    spool.join("jobs").join(id).join("sweep.csv")
}

fn data_rows(csv: &str) -> usize {
    // Everything but the stamp/hole comment lines and the header.
    csv.lines().filter(|l| !l.starts_with('#')).count().saturating_sub(1)
}

/// A spawned serve process that is SIGKILLed if the test panics —
/// `serve` without `--once` waits for work forever, and a failed
/// assertion must not leak a daemon.
struct Serve(std::process::Child);

impl Drop for Serve {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < timeout, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Reference run on its own spool: the uninterrupted CSV every crash
/// variant must reproduce byte for byte.
fn reference_csv(tag: &str, spec: &str) -> String {
    let spool = fresh_spool(&format!("{tag}_ref"));
    let id = submit(&spool, "grid", spec);
    serve_once(&spool, &["--workers", "2"]);
    let text = std::fs::read_to_string(csv_path(&spool, &id)).unwrap();
    std::fs::remove_dir_all(&spool).ok();
    text
}

#[test]
fn sigkilled_supervisor_restarts_to_byte_identical_csv() {
    let reference = reference_csv("kill", grid_spec());
    let spool = fresh_spool("kill");
    let id = submit(&spool, "grid", grid_spec());

    // Start the service, let it land at least one row, then `kill -9`
    // the supervisor (Child::kill is SIGKILL on unix) — workers are
    // orphaned mid-point and self-terminate on the next epoch bump.
    let mut serve = Serve(serve_cmd(&spool, &["--workers", "2"]).spawn().unwrap());
    let victim = csv_path(&spool, &id);
    wait_until("first streamed row", Duration::from_secs(60), || {
        serve.0.try_wait().unwrap().is_none() // supervisor must still be up
            && std::fs::read_to_string(&victim).map(|t| data_rows(&t) >= 1).unwrap_or(false)
    });
    serve.0.kill().unwrap();
    serve.0.wait().unwrap();

    // Restart on the same spool: replay must finish the job, and the
    // final CSV must be byte-identical to the uninterrupted run's.
    serve_once(&spool, &["--workers", "2"]);
    assert!(spool.join("jobs").join(&id).join("DONE").exists(), "restart completes the job");
    let resumed = std::fs::read_to_string(&victim).unwrap();
    assert_eq!(
        resumed, reference,
        "killed-and-restarted job CSV must be byte-identical to an uninterrupted run's"
    );
    std::fs::remove_dir_all(&spool).ok();
}

#[test]
fn hung_worker_loses_lease_points_requeue_and_job_completes() {
    let spec = r#"{"nodes": 32, "intra_gbs": [128, 512], "patterns": ["C3"],
                   "loads": [0.1, 0.2], "workers": 1, "seed": 7}"#;
    let reference = reference_csv("lease", spec);
    let spool = fresh_spool("lease");
    let id = submit(&spool, "grid", spec);

    // One worker slot, and the first worker (w0) hangs before claiming
    // or heartbeating: the job can only finish if the supervisor expires
    // w0's lease, requeues its points, and spawns a replacement.
    let status = serve_cmd(&spool, &["--workers", "1", "--lease-ms", "500", "--once"])
        .env("SAURON_WORK_TEST_HANG", "w0")
        .status()
        .unwrap();
    assert!(status.success(), "serve exited with {status}");

    let dir = spool.join("jobs").join(&id);
    assert!(dir.join("DONE").exists(), "job completes despite the hung worker");
    let journal = std::fs::read_to_string(dir.join("journal.log")).unwrap();
    let requeues: Vec<&str> =
        journal.lines().filter(|l| l.contains("\"ev\": \"requeue\"")).collect();
    assert!(
        !requeues.is_empty() && requeues.iter().all(|l| l.contains("w0")),
        "w0's points are requeued by the lease: {journal}"
    );
    assert!(requeues.iter().all(|l| l.contains("lease expired")), "{journal}");
    let text = std::fs::read_to_string(csv_path(&spool, &id)).unwrap();
    assert_eq!(text, reference, "the replacement worker reproduces the reference CSV");
    std::fs::remove_dir_all(&spool).ok();
}

#[test]
fn sigterm_drains_gracefully_and_resume_completes() {
    let reference = reference_csv("drain", grid_spec());
    let spool = fresh_spool("drain");
    let id = submit(&spool, "grid", grid_spec());

    let mut serve = Serve(serve_cmd(&spool, &["--workers", "2"]).spawn().unwrap());
    let victim = csv_path(&spool, &id);
    wait_until("first streamed row", Duration::from_secs(60), || {
        serve.0.try_wait().unwrap().is_none()
            && std::fs::read_to_string(&victim).map(|t| data_rows(&t) >= 1).unwrap_or(false)
    });
    // Graceful shutdown: SIGTERM via /bin/kill (std exposes only SIGKILL).
    let term = Command::new("kill").arg("-TERM").arg(serve.0.id().to_string()).status().unwrap();
    assert!(term.success(), "kill -TERM failed");
    let status = serve.0.wait().unwrap();
    assert!(status.success(), "drain must exit 0, got {status}");

    // Unless the job squeaked through before the signal landed, the
    // drain is journaled and the job is left resumable.
    let dir = spool.join("jobs").join(&id);
    if !dir.join("DONE").exists() {
        let journal = std::fs::read_to_string(dir.join("journal.log")).unwrap();
        assert!(journal.contains("\"ev\": \"drain\""), "drain journaled: {journal}");
    }
    serve_once(&spool, &["--workers", "2"]);
    assert!(dir.join("DONE").exists());
    let resumed = std::fs::read_to_string(&victim).unwrap();
    assert_eq!(resumed, reference, "drained-and-resumed CSV matches the uninterrupted run");
    std::fs::remove_dir_all(&spool).ok();
}

#[test]
fn exhausted_points_quarantine_as_declared_holes_while_rest_complete() {
    // Phase 1: a healthy two-load run, to read the per-point event
    // counts from the CSV's `events` column.
    let healthy_spec = r#"{"nodes": 32, "intra_gbs": [128], "patterns": ["C3"],
                           "loads": [0.05, 0.45], "workers": 1, "seed": 7}"#;
    let spool = fresh_spool("quarantine_probe");
    let id = submit(&spool, "probe", healthy_spec);
    serve_once(&spool, &["--workers", "1"]);
    let text = std::fs::read_to_string(csv_path(&spool, &id)).unwrap();
    let mut lines = text.lines().filter(|l| !l.starts_with('#'));
    let header = lines.next().unwrap();
    let events_col = header.split(',').position(|c| c == "events").unwrap();
    let events: Vec<u64> = lines
        .map(|l| l.split(',').nth(events_col).unwrap().parse().unwrap())
        .collect();
    assert_eq!(events.len(), 2);
    assert!(events[0] < events[1], "loads must separate event counts: {events:?}");
    std::fs::remove_dir_all(&spool).ok();

    // Phase 2: same grid with an event watchdog between the two counts —
    // the heavy point trips it on every attempt, exhausts the budget,
    // and must be quarantined while the light point completes normally.
    let cap = (events[0] + events[1]) / 2;
    let spec = format!(
        r#"{{"nodes": 32, "intra_gbs": [128], "patterns": ["C3"],
            "loads": [0.05, 0.45], "workers": 1, "seed": 7,
            "limits": {{"max_events": {cap}}}}}"#
    );
    let spool = fresh_spool("quarantine");
    let id = submit(&spool, "capped", &spec);
    serve_once(&spool, &["--workers", "1", "--retries", "1", "--backoff-ms", "1"]);

    let dir = spool.join("jobs").join(&id);
    assert!(dir.join("DONE").exists(), "quarantine must not block job completion");
    let done = std::fs::read_to_string(dir.join("DONE")).unwrap();
    assert!(done.contains("\"quarantined\""), "{done}");
    let text = std::fs::read_to_string(csv_path(&spool, &id)).unwrap();
    assert_eq!(data_rows(&text), 1, "the light point lands:\n{text}");
    assert!(text.contains("# hole 1"), "the heavy point is a declared hole:\n{text}");
    let journal = std::fs::read_to_string(dir.join("journal.log")).unwrap();
    let quarantine: Vec<&str> =
        journal.lines().filter(|l| l.contains("\"ev\": \"quarantine\"")).collect();
    assert_eq!(quarantine.len(), 1, "{journal}");
    assert!(
        quarantine[0].contains("\"idx\": 1") && quarantine[0].contains("\"attempts\": 2"),
        "budget = retries + 1 attempts before quarantine: {}",
        quarantine[0]
    );
    std::fs::remove_dir_all(&spool).ok();
}
