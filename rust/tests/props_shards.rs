//! Event-shard determinism gate.
//!
//! `SimConfig::shards` is a **run-phase performance knob**: lanes share
//! one global sequence counter, so the merged pop order is the single
//! queue's `(Time, seq)` order by construction, and the shard workers
//! only precompute hints the hot path re-validates before use. The
//! contract locked here is total: a sharded run produces a [`SimReport`]
//! bit-identical (every field except `wall_ms`) to the `shards: 1`
//! single-queue engine — across intra fabrics, NIC policies, inter
//! topologies, workloads, coalescing on/off, and **firing** fault plans
//! (faults invalidate hints via the speculation epoch; a stale hint
//! consumed after a fault would show up here first).

use sauron::config::{
    presets, CollOp, CollScope, CollectiveSpec, FabricConfig, FabricKind, FaultAction, FaultEvent,
    FaultPlan, LinkSel, NicPolicy, Pattern, SimConfig, Workload,
};
use sauron::net::world::{BenchMode, NativeProvider, Sim, SimReport};
use sauron::testkit::{forall, Choice, FloatRange, Triple};

/// Compare every result-describing field; only `wall_ms` is excluded.
fn reports_identical(sharded: &SimReport, single: &SimReport) -> Result<(), String> {
    macro_rules! field_eq {
        ($field:ident) => {
            if sharded.$field != single.$field {
                return Err(format!(
                    "field {} differs: {:?} (sharded) vs {:?} (shards=1)",
                    stringify!($field),
                    sharded.$field,
                    single.$field
                ));
            }
        };
    }
    field_eq!(pattern);
    field_eq!(load);
    field_eq!(nodes);
    field_eq!(accels);
    field_eq!(fabric);
    field_eq!(nics);
    field_eq!(inter);
    field_eq!(aggregated_intra_gbs);
    field_eq!(offered_gbs);
    field_eq!(intra_tput_gbs);
    field_eq!(intra_drain_gbs);
    field_eq!(intra_lat);
    field_eq!(inter_tput_gbs);
    field_eq!(inter_drain_gbs);
    field_eq!(fct);
    field_eq!(intra_wire_gbs);
    field_eq!(inter_wire_gbs);
    field_eq!(drop_frac);
    field_eq!(delivered_msgs);
    field_eq!(offered_msgs);
    field_eq!(events);
    field_eq!(table_misses);
    field_eq!(dropped_units);
    field_eq!(coll_op);
    field_eq!(coll_size_b);
    field_eq!(coll_iters);
    field_eq!(coll_time);
    field_eq!(coll_pred_ns);
    Ok(())
}

fn run(cfg: SimConfig) -> Result<SimReport, String> {
    Sim::new(cfg, &NativeProvider, BenchMode::None)
        .map_err(|e| format!("build: {e:#}"))?
        .try_run()
        .map_err(|e| format!("run: {e:#}"))
}

/// Run `cfg` at shards ∈ {1, 2, 4} and demand bit-identical reports.
fn identical_across_shards(cfg: SimConfig) -> Result<(), String> {
    let mut single = cfg.clone();
    single.shards = 1;
    let base = run(single)?;
    for shards in [2u32, 4] {
        let mut c = cfg.clone();
        c.shards = shards;
        let r = run(c)?;
        reports_identical(&r, &base).map_err(|e| format!("shards={shards}: {e}"))?;
    }
    Ok(())
}

fn fabric_cfg(
    kind: FabricKind,
    nics: usize,
    policy: NicPolicy,
    load: f64,
    pattern: Pattern,
    seed: u64,
) -> SimConfig {
    let mut fab = FabricConfig::new(kind, nics);
    fab.nic_policy = policy;
    let mut cfg = presets::with_fabric(presets::scaleout(32, 256.0, pattern, load), fab);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    cfg.seed = seed;
    cfg
}

#[test]
fn prop_sharded_bit_identical_across_fabrics() {
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[
            (1usize, NicPolicy::LocalRank),
            (2, NicPolicy::LocalRank),
            (2, NicPolicy::RoundRobin),
        ]),
        FloatRange { lo: 0.05, hi: 0.85 },
    );
    forall(0x5AD1, 10, &gen, |&(kind, (nics, policy), load)| {
        let cfg = fabric_cfg(kind, nics, policy, load, Pattern::C1, 0x5A);
        identical_across_shards(cfg).map_err(|e| format!("{kind:?}/{nics}nic/{policy:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_sharded_bit_identical_across_inter_kinds_and_workloads() {
    let gen = Triple(
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        Choice(&[None, Some(CollOp::RingAllReduce), Some(CollOp::HierarchicalAllReduce)]),
        FloatRange { lo: 0.05, hi: 0.5 },
    );
    forall(0x5AD2, 9, &gen, |&(inter, op, load)| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, load);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        cfg.seed = 0x5B;
        if let Some(op) = op {
            let scope = if op == CollOp::HierarchicalAllReduce {
                CollScope::Global
            } else {
                CollScope::PerNode
            };
            cfg.workload =
                Workload::Collective(CollectiveSpec { op, scope, size_b: 32 * 1024, iters: 2 });
        }
        identical_across_shards(cfg).map_err(|e| format!("{inter}/{op:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_sharded_bit_identical_with_firing_faults() {
    // Firing plans are where the speculation epoch earns its keep: a
    // hint computed pre-fault must never be consumed post-fault. The
    // plan runs a full degrade → kill → recover cycle through the
    // measure window.
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        FloatRange { lo: 0.1, hi: 0.5 },
    );
    forall(0x5AD3, 9, &gen, |&(kind, inter, load)| {
        let mut cfg = fabric_cfg(kind, 2, NicPolicy::RoundRobin, load, Pattern::C1, 0x5C);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        let sel = LinkSel::NicUp { node: 0, nic: 0 };
        cfg.faults = FaultPlan {
            events: vec![
                FaultEvent {
                    at_us: 7.0,
                    action: FaultAction::LinkDegrade { factor: 0.5 },
                    sel: Some(sel),
                },
                FaultEvent { at_us: 9.0, action: FaultAction::LinkDown, sel: Some(sel) },
                FaultEvent { at_us: 12.0, action: FaultAction::Recover, sel: Some(sel) },
            ],
        };
        identical_across_shards(cfg).map_err(|e| format!("{kind:?}/{inter}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_sharded_bit_identical_with_coalescing_off() {
    // Shards × scalar stepping: with trains disabled every unit is its
    // own event, maximizing cross-shard interleaving at one timestamp.
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[Pattern::C1, Pattern::C3, Pattern::C5]),
        FloatRange { lo: 0.1, hi: 0.6 },
    );
    forall(0x5AD4, 8, &gen, |&(kind, pattern, load)| {
        let mut cfg = fabric_cfg(kind, 1, NicPolicy::LocalRank, load, pattern, 0x5D);
        cfg.coalescing = false;
        identical_across_shards(cfg).map_err(|e| format!("{kind:?}/{pattern:?}/{load:.3}: {e}"))
    });
}

#[test]
fn shard_count_beyond_node_count_clamps_and_matches() {
    // 1024 shards on a 32-node world: the ShardMap clamps to the node
    // count; the run must still be bit-identical to the plain engine.
    let cfg = fabric_cfg(FabricKind::SwitchStar, 1, NicPolicy::LocalRank, 0.4, Pattern::C3, 0x5E);
    let base = run(cfg.clone()).unwrap();
    let mut big = cfg;
    big.shards = 1024;
    let r = run(big).unwrap();
    reports_identical(&r, &base).unwrap();
}
