//! Fault-injection equivalence + graceful-degradation suite.
//!
//! The anchor property mirrors `props_reuse`: a [`FaultPlan`] is a
//! **run-phase** delta, so a point carrying an empty or never-firing
//! plan must produce a [`SimReport`] bit-identical (every field except
//! `wall_ms`) to the same point with no plan at all — across every
//! intra fabric, NIC policy, inter topology and workload kind. The
//! zero-overhead-when-off contract would silently rot without it.
//!
//! On top of that: degraded links never drop traffic, NIC failures
//! fail over without stopping the run on any fabric × inter-kind
//! combination, and the `SimConfig::limits` watchdog is observational
//! until it trips — at which point the error is the structured
//! [`SimError::LimitExceeded`] the crash-safe sweep isolates.

use sauron::config::{
    presets, CollOp, CollScope, CollectiveSpec, FabricConfig, FabricKind, FaultAction, FaultEvent,
    FaultPlan, LinkSel, NicPolicy, Pattern, SimConfig, Workload,
};
use sauron::net::world::{BenchMode, NativeProvider, Sim, SimError, SimReport};
use sauron::testkit::{forall, Choice, FloatRange, Triple};

/// Compare every result-describing field; only `wall_ms` is excluded.
fn reports_identical(planned: &SimReport, plain: &SimReport) -> Result<(), String> {
    macro_rules! field_eq {
        ($field:ident) => {
            if planned.$field != plain.$field {
                return Err(format!(
                    "field {} differs: {:?} (with plan) vs {:?} (without)",
                    stringify!($field),
                    planned.$field,
                    plain.$field
                ));
            }
        };
    }
    field_eq!(pattern);
    field_eq!(load);
    field_eq!(nodes);
    field_eq!(accels);
    field_eq!(fabric);
    field_eq!(nics);
    field_eq!(inter);
    field_eq!(aggregated_intra_gbs);
    field_eq!(offered_gbs);
    field_eq!(intra_tput_gbs);
    field_eq!(intra_drain_gbs);
    field_eq!(intra_lat);
    field_eq!(inter_tput_gbs);
    field_eq!(inter_drain_gbs);
    field_eq!(fct);
    field_eq!(intra_wire_gbs);
    field_eq!(inter_wire_gbs);
    field_eq!(drop_frac);
    field_eq!(delivered_msgs);
    field_eq!(offered_msgs);
    field_eq!(events);
    field_eq!(table_misses);
    field_eq!(dropped_units);
    field_eq!(coll_op);
    field_eq!(coll_size_b);
    field_eq!(coll_iters);
    field_eq!(coll_time);
    field_eq!(coll_pred_ns);
    Ok(())
}

fn run(cfg: SimConfig) -> Result<SimReport, String> {
    Sim::new(cfg, &NativeProvider, BenchMode::None)
        .map_err(|e| format!("build: {e:#}"))?
        .try_run()
        .map_err(|e| format!("run: {e:#}"))
}

fn fabric_cfg(
    kind: FabricKind,
    nics: usize,
    policy: NicPolicy,
    load: f64,
    pattern: Pattern,
    seed: u64,
) -> SimConfig {
    let mut fab = FabricConfig::new(kind, nics);
    fab.nic_policy = policy;
    let mut cfg = presets::with_fabric(presets::scaleout(32, 256.0, pattern, load), fab);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    cfg.seed = seed;
    cfg
}

fn with_plan(mut cfg: SimConfig, events: Vec<FaultEvent>) -> SimConfig {
    cfg.faults = FaultPlan { events };
    cfg
}

/// A full down/degrade/recover cycle scheduled far past the end of the
/// run: resolved and armed, never applied.
fn never_firing(sel: LinkSel) -> Vec<FaultEvent> {
    vec![
        FaultEvent {
            at_us: 1e9,
            action: FaultAction::LinkDegrade { factor: 0.5 },
            sel: Some(sel.clone()),
        },
        FaultEvent { at_us: 2e9, action: FaultAction::LinkDown, sel: Some(sel.clone()) },
        FaultEvent { at_us: 3e9, action: FaultAction::Recover, sel: Some(sel) },
    ]
}

#[test]
fn prop_never_firing_plan_bit_identical_across_fabrics() {
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[
            (1usize, NicPolicy::LocalRank),
            (2, NicPolicy::LocalRank),
            (2, NicPolicy::RoundRobin),
        ]),
        FloatRange { lo: 0.05, hi: 0.45 },
    );
    forall(0xFA017, 10, &gen, |&(kind, (nics, policy), load)| {
        let base = fabric_cfg(kind, nics, policy, load, Pattern::C1, 0xBEE);
        let planned = with_plan(base.clone(), never_firing(LinkSel::NicUp { node: 0, nic: 0 }));
        let plain = run(base)?;
        let armed = run(planned)?;
        reports_identical(&armed, &plain)
            .map_err(|e| format!("{kind:?}/{nics}nic/{policy:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_never_firing_plan_bit_identical_across_inter_kinds_and_workloads() {
    let gen = Triple(
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        Choice(&[None, Some(CollOp::RingAllReduce), Some(CollOp::HierarchicalAllReduce)]),
        FloatRange { lo: 0.05, hi: 0.35 },
    );
    forall(0xFA018, 9, &gen, |&(inter, op, load)| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, load);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        cfg.seed = 0xFA;
        if let Some(op) = op {
            let scope = if op == CollOp::HierarchicalAllReduce {
                CollScope::Global
            } else {
                CollScope::PerNode
            };
            cfg.workload =
                Workload::Collective(CollectiveSpec { op, scope, size_b: 32 * 1024, iters: 2 });
        }
        let planned = with_plan(cfg.clone(), never_firing(LinkSel::NicUp { node: 3, nic: 0 }));
        let plain = run(cfg)?;
        let armed = run(planned)?;
        reports_identical(&armed, &plain).map_err(|e| format!("{inter}/{op:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_generous_limits_are_observational() {
    // The watchdog runs the engine in bounded chunks instead of one
    // `run_until` — that mechanical difference must be invisible
    // whenever the budget doesn't trip.
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[Pattern::C1, Pattern::C5]),
        FloatRange { lo: 0.05, hi: 0.4 },
    );
    forall(0xFA019, 8, &gen, |&(kind, pattern, load)| {
        let base = fabric_cfg(kind, 1, NicPolicy::LocalRank, load, pattern, 3);
        let mut capped = base.clone();
        capped.limits.max_events = u64::MAX / 2;
        capped.limits.max_wall_ms = 3_600_000.0;
        let plain = run(base)?;
        let under_budget = run(capped)?;
        reports_identical(&under_budget, &plain)
            .map_err(|e| format!("{kind:?}/{pattern:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_degrade_never_drops_and_completes() {
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[0.25f64, 0.5, 0.75]),
        FloatRange { lo: 0.05, hi: 0.3 },
    );
    forall(0xFA01A, 8, &gen, |&(kind, factor, load)| {
        let base = fabric_cfg(kind, 1, NicPolicy::LocalRank, load, Pattern::C1, 0xDE6);
        let sel = LinkSel::NicUp { node: 0, nic: 0 };
        let planned = with_plan(
            base,
            vec![
                FaultEvent {
                    at_us: 11.0,
                    action: FaultAction::LinkDegrade { factor },
                    sel: Some(sel.clone()),
                },
                FaultEvent { at_us: 14.0, action: FaultAction::Recover, sel: Some(sel) },
            ],
        );
        let r = run(planned).map_err(|e| format!("{kind:?}/{factor}/{load:.3}: {e}"))?;
        if r.dropped_units != 0 {
            return Err(format!(
                "{kind:?}/{factor}/{load:.3}: degrade dropped {} units",
                r.dropped_units
            ));
        }
        if r.delivered_msgs == 0 {
            return Err(format!("{kind:?}/{factor}/{load:.3}: nothing delivered"));
        }
        Ok(())
    });
}

#[test]
fn prop_nic_down_fails_over_on_every_fabric_and_inter_kind() {
    // Killing one of two NICs mid-measure must leave an open-loop run
    // degraded but alive: messages keep completing and inter traffic
    // keeps flowing through the surviving NIC, whatever the fabric the
    // NICs hang off or the inter topology behind them.
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        FloatRange { lo: 0.1, hi: 0.3 },
    );
    forall(0xFA01B, 9, &gen, |&(kind, inter, load)| {
        let mut cfg = fabric_cfg(kind, 2, NicPolicy::RoundRobin, load, Pattern::C1, 0x0FF);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        let planned = with_plan(
            cfg,
            vec![FaultEvent {
                at_us: 12.0,
                action: FaultAction::NicDown { node: 0, nic: 0 },
                sel: None,
            }],
        );
        let r = run(planned).map_err(|e| format!("{kind:?}/{inter}/{load:.3}: {e}"))?;
        if r.delivered_msgs == 0 {
            return Err(format!("{kind:?}/{inter}/{load:.3}: run starved after NIC failure"));
        }
        if r.inter_tput_gbs <= 0.0 {
            return Err(format!("{kind:?}/{inter}/{load:.3}: failover carried no inter traffic"));
        }
        Ok(())
    });
}

#[test]
fn pcie_degrade_slows_memoized_payload_sizes() {
    // Memo-staleness regression (the `ser_time` audit): open-loop
    // traffic serializes ONE payload size per link, so after warm-up
    // every PCIe serialization is answered by the per-link last-hit
    // memo, never the table search. The memo caches the PRE-degrade
    // base and the fault factor is applied after the memo read — if a
    // "faster" memo ever cached the post-factor value (or the factor
    // were skipped on memo hits), a mid-run degrade of a PCIe accel
    // lane would be invisible to steady same-payload traffic. Lock the
    // observable: degrading the lane must strictly worsen intra
    // latency, without dropping anything.
    let base = fabric_cfg(FabricKind::SwitchStar, 1, NicPolicy::LocalRank, 0.3, Pattern::C1, 0x5E);
    let lane = sauron::net::Topology::new(&base).accel_up(0, 0);
    let planned = with_plan(
        base.clone(),
        vec![FaultEvent {
            at_us: 6.0,
            action: FaultAction::LinkDegrade { factor: 0.1 },
            sel: Some(LinkSel::Id { link: lane }),
        }],
    );
    let plain = run(base).unwrap();
    let degraded = run(planned).unwrap();
    assert!(
        degraded.intra_lat.mean_ns > plain.intra_lat.mean_ns,
        "degrading a PCIe lane mid-run was invisible to memoized traffic: \
         {} ns (degraded) vs {} ns (plain)",
        degraded.intra_lat.mean_ns,
        plain.intra_lat.mean_ns
    );
    assert_eq!(degraded.dropped_units, 0, "degrade must never drop");
    assert!(degraded.delivered_msgs > 0);
}

#[test]
fn prop_unit_factor_degrade_changes_nothing_but_event_count() {
    // A LinkDegrade{factor: 1.0} that actually FIRES exercises the
    // whole fault edge — train settling at the fault instant, the
    // train-construction fault cap, hint invalidation, the memo audit —
    // while leaving link rates untouched. Every delivery time must be
    // bit-identical to the fault-free run; only `events` may differ
    // (trains capped at the fault instant split into more TxEnds at the
    // same timestamps) and `table_misses` must agree exactly.
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&["leaf_spine", "fat_tree3", "dragonfly"]),
        FloatRange { lo: 0.1, hi: 0.4 },
    );
    forall(0xFA01C, 9, &gen, |&(kind, inter, load)| {
        let mut cfg = fabric_cfg(kind, 1, NicPolicy::LocalRank, load, Pattern::C2, 0x1F0);
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        let lane = sauron::net::Topology::new(&cfg).accel_up(1, 0);
        let planned = with_plan(
            cfg.clone(),
            vec![FaultEvent {
                at_us: 8.0,
                action: FaultAction::LinkDegrade { factor: 1.0 },
                sel: Some(LinkSel::Id { link: lane }),
            }],
        );
        let plain = run(cfg)?;
        let armed = run(planned)?;
        // `reports_identical` short-circuits at `events`, so pin the
        // lookup-path invariant explicitly first.
        if armed.table_misses != plain.table_misses {
            return Err(format!(
                "{kind:?}/{inter}/{load:.3}: table_misses differs: {} vs {}",
                armed.table_misses, plain.table_misses
            ));
        }
        match reports_identical(&armed, &plain) {
            Ok(()) => Ok(()),
            // Only the event count may legitimately differ (see above).
            Err(e) if e.starts_with("field events differs") => Ok(()),
            Err(e) => Err(format!("{kind:?}/{inter}/{load:.3}: {e}")),
        }
    });
}

#[test]
fn watchdog_event_limit_trips_with_structured_error() {
    let mut cfg = fabric_cfg(FabricKind::SwitchStar, 1, NicPolicy::LocalRank, 0.3, Pattern::C3, 1);
    cfg.limits.max_events = 800;
    let err = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().try_run().unwrap_err();
    match err.downcast_ref::<SimError>() {
        Some(SimError::LimitExceeded { events, .. }) => {
            assert!(*events <= 800, "budget overshot: {events}")
        }
        other => panic!("expected LimitExceeded, got {other:?} ({err:#})"),
    }
}

#[test]
fn watchdog_wall_time_limit_trips_with_structured_error() {
    let mut cfg = fabric_cfg(FabricKind::SwitchStar, 1, NicPolicy::LocalRank, 0.3, Pattern::C3, 1);
    cfg.limits.max_wall_ms = 1e-6; // ~1 ns: trips at the first budget check
    let err = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().try_run().unwrap_err();
    assert!(
        matches!(err.downcast_ref::<SimError>(), Some(SimError::LimitExceeded { .. })),
        "expected LimitExceeded, got {err:#}"
    );
    assert!(format!("{err:#}").contains("watchdog"), "{err:#}");
}
