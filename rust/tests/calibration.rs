//! Calibration-against-hardware conformance tests.
//!
//! Each golden fixture under `fixtures/calibration/` encodes published
//! GPU-to-GPU bandwidth/latency points measured on a real system
//! (De Sensi et al., arXiv:2408.14090). These tests replay every
//! fixture through `calibration::run_fixture` on its calibrated preset
//! and fail loudly if any non-divergent point lands outside its
//! tolerance.
//!
//! The `#[ignore]`d `strict_*` tests assert the *declared* divergences
//! too: they are expected to fail today (the gaps are real model
//! limitations, documented in EXPERIMENTS.md "Calibration"), and start
//! passing the day a model fix closes the gap — run them after any
//! intra-fabric or host-path change:
//! `cargo test --test calibration -- --ignored`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use sauron::calibration::{self, Fixture, PointReport, PointStatus};
use sauron::net::world::NativeProvider;
use sauron::serial::json::{FromJson, Value};

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join("calibration")
}

fn run(file: &str) -> Vec<PointReport> {
    let fx = Fixture::load(&fixtures_dir().join(file)).expect("fixture loads");
    calibration::run_fixture(&NativeProvider, &fx).expect("fixture runs")
}

/// Gate: every point that is not a declared divergence must be inside
/// its tolerance. Prints the whole report on failure so the diagnostic
/// carries expected-vs-simulated for every point, not just the bad one.
fn assert_conformant(points: &[PointReport]) {
    let fails: Vec<&PointReport> =
        points.iter().filter(|p| p.status == PointStatus::Fail).collect();
    assert!(
        fails.is_empty(),
        "{} calibration point(s) outside tolerance:\n{}\nfull report:\n{}",
        fails.len(),
        fails.iter().map(|p| p.to_string()).collect::<Vec<_>>().join("\n"),
        points.iter().map(|p| p.to_string()).collect::<Vec<_>>().join("\n")
    );
}

/// Strict gate for the `#[ignore]`d tests: the declared divergences
/// must be inside tolerance too. Failing here is the *expected* state;
/// a pass means a model fix closed the gap — delete the corresponding
/// `known_divergence` flag from the fixture and update EXPERIMENTS.md.
fn assert_divergences_closed(points: &[PointReport]) {
    let open: Vec<String> = points
        .iter()
        .filter(|p| p.status == PointStatus::KnownDivergence && p.rel_err > p.tolerance)
        .map(|p| format!("{p}\n  note: {}", p.note))
        .collect();
    assert!(
        open.is_empty(),
        "declared divergences still open (expected until the model gap is fixed — see \
         EXPERIMENTS.md 'Calibration'):\n{}",
        open.join("\n")
    );
}

#[test]
fn fixture_set_loads_and_covers_three_systems_two_paths() {
    let fixtures = Fixture::load_dir(&fixtures_dir()).expect("fixtures load and validate");
    let mut paths_by_system: BTreeMap<String, Vec<&'static str>> = BTreeMap::new();
    for fx in &fixtures {
        paths_by_system.entry(fx.system.clone()).or_default().push(fx.path.name());
    }
    assert!(
        paths_by_system.len() >= 3,
        "need >= 3 measured systems, have {:?}",
        paths_by_system.keys().collect::<Vec<_>>()
    );
    for (system, mut paths) in paths_by_system {
        paths.sort_unstable();
        paths.dedup();
        assert!(
            paths.len() >= 2,
            "system '{system}' needs >= 2 distinct path types, has {paths:?}"
        );
    }
    // Inter-NIC coverage is what anchors the fixtures to the network
    // model validated against the CELLIA paper; require it everywhere.
    for fx in &fixtures {
        assert!(!fx.bandwidth.is_empty(), "{}/{}: no bandwidth curve", fx.system, fx.path.name());
        assert!(!fx.latency.is_empty(), "{}/{}: no latency curve", fx.system, fx.path.name());
    }
}

#[test]
fn fixtures_round_trip_through_json() {
    for fx in Fixture::load_dir(&fixtures_dir()).unwrap() {
        let back = Fixture::from_json(&fx.to_json()).unwrap();
        assert_eq!(fx, back, "{}/{}: JSON round trip drifted", fx.system, fx.path.name());
        let reparsed =
            Fixture::from_json(&Value::parse(&fx.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(fx, reparsed);
    }
}

#[test]
fn conformance_leonardo_intra_nvlink() {
    assert_conformant(&run("leonardo_intra_nvlink.json"));
}

#[test]
fn conformance_leonardo_intra_pcie() {
    assert_conformant(&run("leonardo_intra_pcie.json"));
}

#[test]
fn conformance_leonardo_inter_nic() {
    assert_conformant(&run("leonardo_inter_nic.json"));
}

#[test]
fn conformance_lumi_intra_if() {
    assert_conformant(&run("lumi_intra_if.json"));
}

#[test]
fn conformance_lumi_inter_nic() {
    assert_conformant(&run("lumi_inter_nic.json"));
}

#[test]
fn conformance_alps_intra_nvlink() {
    assert_conformant(&run("alps_intra_nvlink.json"));
}

#[test]
fn conformance_alps_inter_nic() {
    assert_conformant(&run("alps_inter_nic.json"));
}

// The known-divergence points, gated only under --ignored. Expected to
// FAIL until the corresponding model gap is closed; see EXPERIMENTS.md
// "Calibration" for the per-gap analysis.

#[test]
#[ignore = "mid-size intra bandwidth: no per-message launch overhead in the intra path \
            (EXPERIMENTS.md 'Calibration'); passes once an intra ramp model lands"]
fn strict_intra_ramp_divergence() {
    let mut points = run("leonardo_intra_nvlink.json");
    points.extend(run("lumi_intra_if.json"));
    points.extend(run("alps_intra_nvlink.json"));
    assert_divergences_closed(&points);
}

#[test]
#[ignore = "host-tree large-message latency: whole-message store-and-forward per bridge hop \
            vs pipelined DMA on hardware (EXPERIMENTS.md 'Calibration')"]
fn strict_host_tree_store_and_forward_divergence() {
    assert_divergences_closed(&run("leonardo_intra_pcie.json"));
}
