//! Property tests: RLFT topology construction and D-mod-K routing
//! (DESIGN.md test inventory — routing properties), across every
//! pluggable intra fabric and NIC count.

use sauron::config::{presets, FabricConfig, FabricKind, NicPolicy, Pattern, SimConfig};
use sauron::net::{Kind, Topology};
use sauron::testkit::{forall, Choice, IntRange, Pair, Triple};

fn topo(nodes: usize) -> Topology {
    Topology::new(&presets::scaleout(nodes, 128.0, Pattern::C1, 0.5))
}

/// Walk a unit's full path from src accel to dst accel; return link kinds.
/// Every visited link id must be in bounds and the walk must terminate.
fn walk(t: &Topology, src: u32, dst: u32) -> Result<Vec<Kind>, String> {
    let mut link = t.egress_link(src, dst);
    let mut kinds = Vec::new();
    let mut hops = 0u32;
    loop {
        if link >= t.total_links() {
            return Err(format!("link id {link} out of bounds ({}): {kinds:?}", t.total_links()));
        }
        kinds.push(t.kind_of(link));
        hops += 1;
        if hops > t.max_path_links() {
            return Err(format!("routing loop after {hops} hops: {kinds:?}"));
        }
        match t.next_hop(*kinds.last().unwrap(), src, dst) {
            Some(next) => link = next,
            None => return Ok(kinds),
        }
    }
}

#[test]
fn prop_every_pair_delivers_within_8_hops() {
    let gen = Triple(
        Choice(&[32usize, 128]),
        IntRange { lo: 0, hi: 1023 },
        IntRange { lo: 0, hi: 1023 },
    );
    forall(0xA11CE, 400, &gen, |&(nodes, s, d)| {
        let t = topo(nodes);
        let total = t.total_accels() as u64;
        let (src, dst) = ((s % total) as u32, (d % total) as u32);
        if src == dst {
            return Ok(());
        }
        let kinds = walk(&t, src, dst)?;
        // Terminates at the destination accelerator's down-link.
        match *kinds.last().unwrap() {
            Kind::AccelDown { node, accel } => {
                if node != t.accel_node(dst) || accel != t.accel_local(dst) {
                    return Err(format!("delivered to wrong accel: {kinds:?}"));
                }
            }
            other => return Err(format!("path ends at {other:?}")),
        }
        if kinds.len() > 8 {
            return Err(format!("path too long ({}): {kinds:?}", kinds.len()));
        }
        Ok(())
    });
}

/// The satellite property: every link id produced by routing — walking
/// from every source to every destination — is in-bounds and the walk
/// terminates at a link that delivers to the destination, across
/// randomized `(nodes, leaves, spines, accels, inter kind, fabric,
/// nics, policy)` including all the new fabrics and every pluggable
/// inter-node topology.
#[test]
fn prop_routing_in_bounds_and_terminates_for_every_fabric() {
    let gen = Triple(
        Pair(
            Choice(&[4usize, 8, 16, 32]), // nodes
            Choice(&[1usize, 2, 4, 0]),   // leaves divisor selector (0 = leaves == nodes)
        ),
        Triple(
            Choice(&[1usize, 2, 3, 4]), // spines
            Choice(&[1usize, 2, 4, 8]), // accels per node
            Choice(&["leaf_spine", "fat_tree3", "dragonfly"]), // inter kind
        ),
        Pair(
            Choice(&FabricKind::ALL),
            Pair(
                Choice(&[1usize, 2, 3, 4, 8]), // nics
                Choice(&[NicPolicy::LocalRank, NicPolicy::RoundRobin]),
            ),
        ),
    );
    forall(0xFAB, 80, &gen, |&((nodes, ldiv), (spines, accels, inter), (fabric, (nics, policy)))| {
        let leaves = if ldiv == 0 { nodes } else { nodes / ldiv.min(nodes) };
        let mut cfg = presets::scaleout(32, 128.0, Pattern::C1, 0.5);
        cfg.node.accels_per_node = accels;
        cfg.inter.nodes = nodes;
        cfg.inter.leaves = leaves;
        cfg.inter.spines = spines;
        cfg.inter.kind = presets::default_inter_kind(inter, leaves, spines);
        cfg.node.fabric = FabricConfig::new(fabric, nics);
        cfg.node.fabric.nic_policy = policy;
        // Degenerate single-accel Ring/Mesh layouts have intra_stride 0
        // (their link-id constructors would alias the NIC staging
        // block); validate() must reject them with an actionable error.
        if accels == 1 && matches!(fabric, FabricKind::Ring | FabricKind::Mesh) {
            let err = cfg
                .validate()
                .err()
                .ok_or_else(|| format!("{fabric:?} with accels_per_node=1 must be rejected"))?;
            if !err.contains("accels_per_node == 1") {
                return Err(format!("{fabric:?} degenerate error not actionable: {err}"));
            }
            return Ok(());
        }
        cfg.validate().map_err(|e| format!("config should be valid: {e}"))?;
        let t = Topology::new(&cfg);
        let total = t.total_accels();
        for src in 0..total {
            for dst in 0..total {
                if src == dst {
                    continue;
                }
                let kinds = walk(&t, src, dst)
                    .map_err(|e| format!("{fabric:?}/{inter}/{nics}nic {src}->{dst}: {e}"))?;
                let last = *kinds.last().unwrap();
                if !t.delivers(last, dst) {
                    return Err(format!(
                        "{fabric:?}/{inter}/{nics}nic {src}->{dst}: terminal {last:?} does not deliver"
                    ));
                }
                // Intra pairs must never leave the node.
                if t.accel_node(src) == t.accel_node(dst)
                    && kinds.iter().any(|k| {
                        matches!(
                            k,
                            Kind::NicUp { .. }
                                | Kind::LeafUp { .. }
                                | Kind::SpineDown { .. }
                                | Kind::AggUp { .. }
                                | Kind::AggDown { .. }
                                | Kind::CoreUp { .. }
                                | Kind::CoreDown { .. }
                                | Kind::DfLocal { .. }
                                | Kind::DfGlobal { .. }
                        )
                    })
                {
                    return Err(format!(
                        "{fabric:?}/{inter} intra pair {src}->{dst} crossed the NIC: {kinds:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn uneven_and_degenerate_layouts_fail_at_config_time() {
    // The old `node / (nodes / leaves)` mapping silently produced leaf
    // indices == leaves when nodes % leaves != 0 (corrupting
    // spine_down/leaf_up ids into other links' slots) and panicked with
    // a divide-by-zero when leaves > nodes. Both must now be rejected
    // with an actionable error before any topology exists.
    let base = || presets::scaleout(32, 128.0, Pattern::C1, 0.5);
    for leaves in [3usize, 5, 7, 9, 12, 20, 31, 33, 64, 100] {
        let mut cfg = base();
        cfg.inter.leaves = leaves;
        let err = cfg.validate().expect_err(&format!("leaves={leaves} must be rejected"));
        assert!(err.contains("divide evenly"), "leaves={leaves}: {err}");
        assert!(
            sauron::net::world::Sim::new(
                cfg,
                &sauron::net::world::NativeProvider,
                sauron::net::world::BenchMode::None
            )
            .is_err(),
            "world construction must also reject leaves={leaves}"
        );
    }
    // Every divisor of 32 is legal and maps each node to a leaf < leaves.
    for leaves in [1usize, 2, 4, 8, 16, 32] {
        let mut cfg = base();
        cfg.inter.leaves = leaves;
        cfg.validate().unwrap_or_else(|e| panic!("leaves={leaves}: {e}"));
        let t = Topology::new(&cfg);
        for node in 0..t.nodes {
            assert!(t.node_leaf(node) < t.leaves, "node {node} mapped past the last leaf");
        }
    }
}

#[test]
fn prop_intra_pairs_never_touch_the_nic() {
    let gen = Triple(Choice(&[32usize, 128]), IntRange { lo: 0, hi: 1023 }, IntRange { lo: 0, hi: 6 });
    forall(0xB0B, 300, &gen, |&(nodes, s, off)| {
        let t = topo(nodes);
        let total = t.total_accels() as u64;
        let src = (s % total) as u32;
        let node = t.accel_node(src);
        let a = t.accels_per_node as u64;
        let dst_local = (t.accel_local(src) as u64 + 1 + off) % a;
        let dst = node * t.accels_per_node + dst_local as u32;
        if dst == src {
            return Ok(());
        }
        let kinds = walk(&t, src, dst)?;
        if kinds.len() != 2 {
            return Err(format!("intra path must be 2 hops, got {kinds:?}"));
        }
        if kinds.iter().any(|k| {
            matches!(
                k,
                Kind::NicUp { .. } | Kind::NicDown { .. } | Kind::SwToNic { .. } | Kind::LeafUp { .. }
            )
        }) {
            return Err(format!("intra path crossed NIC: {kinds:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dmodk_spreads_destinations_evenly() {
    for nodes in [32usize, 128] {
        let t = topo(nodes);
        let mut counts = vec![0u32; t.spines as usize];
        for d in 0..t.nodes {
            counts[t.dmodk_spine(d) as usize] += 1;
        }
        let expect = (t.nodes / t.spines) as u32;
        assert!(counts.iter().all(|&c| c == expect), "{nodes} nodes: {counts:?}");
    }
}

#[test]
fn prop_dmodk_imbalance_is_bounded_when_nodes_dont_divide() {
    // Satellite bugfix: `dmodk_spine` is `dst_node % spines`, so when
    // `nodes % spines != 0` the low-id spines serve one extra
    // destination each. That imbalance is intentional (static D-mod-K,
    // documented in docs/architecture.md); this property pins it down:
    // counts are the ceil/floor of nodes/spines, the ceil counts land
    // on spines `0..nodes % spines`, and max-min never exceeds 1.
    for (nodes, leaves, spines) in
        [(30usize, 6usize, 4usize), (28, 7, 3), (10, 10, 4), (32, 8, 5), (12, 4, 7)]
    {
        let mut cfg = presets::scaleout(32, 128.0, Pattern::C1, 0.5);
        cfg.inter.nodes = nodes;
        cfg.inter.leaves = leaves;
        cfg.inter.spines = spines;
        cfg.validate().unwrap_or_else(|e| panic!("{nodes}n/{leaves}l/{spines}s: {e}"));
        let t = Topology::new(&cfg);
        let mut counts = vec![0u32; spines];
        for d in 0..t.nodes {
            counts[t.dmodk_spine(d) as usize] += 1;
        }
        let floor = (nodes / spines) as u32;
        let rem = nodes % spines;
        for (s, &c) in counts.iter().enumerate() {
            let expect = floor + u32::from(s < rem);
            assert_eq!(c, expect, "{nodes} nodes / {spines} spines, spine {s}: {counts:?}");
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "{nodes} nodes / {spines} spines: {counts:?}");
    }
}

#[test]
fn prop_same_destination_same_spine() {
    // D-mod-K: the spine serving a destination is source-independent ->
    // every destination has a unique down-path (contention-free ordering).
    let gen = Triple(
        Choice(&[32usize, 128]),
        IntRange { lo: 0, hi: 1023 },
        IntRange { lo: 0, hi: 1023 },
    );
    forall(0xD0D0, 300, &gen, |&(nodes, s1, s2)| {
        let t = topo(nodes);
        let total = t.total_accels() as u64;
        let dst = ((17 % t.nodes) * t.accels_per_node) as u32;
        let (a, b) = ((s1 % total) as u32, (s2 % total) as u32);
        let spine_of = |src: u32| -> Option<u32> {
            if t.accel_node(src) == t.accel_node(dst) {
                return None;
            }
            walk(&t, src, dst).unwrap().iter().find_map(|k| match k {
                Kind::SpineDown { spine, .. } => Some(*spine),
                _ => None,
            })
        };
        match (spine_of(a), spine_of(b)) {
            (Some(x), Some(y)) if x != y => Err(format!("dst {dst}: spines {x} vs {y}")),
            _ => Ok(()),
        }
    });
}

#[test]
fn prop_link_ids_bijective() {
    let gen = Pair(
        Choice(&[2usize, 8, 32, 128]),
        Pair(Choice(&FabricKind::ALL), Choice(&[1usize, 2, 4])),
    );
    forall(0x1D5, 40, &gen, |&(nodes, (fabric, nics))| {
        for inter in ["leaf_spine", "fat_tree3", "dragonfly"] {
            let mut cfg = presets::scaleout(nodes, 128.0, Pattern::C1, 0.5);
            cfg.node.fabric = FabricConfig::new(fabric, nics);
            cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
            let t = Topology::new(&cfg);
            for link in 0..t.total_links() {
                let kind = t.kind_of(link);
                let back = match kind {
                    Kind::AccelUp { node, accel } => t.accel_up(node, accel),
                    Kind::AccelDown { node, accel } => t.accel_down(node, accel),
                    Kind::MeshLane { node, from, to } => t.mesh_lane(node, from, to),
                    Kind::RingHop { node, from } => t.ring_hop(node, from),
                    Kind::HostUp { node } => t.host_up(node),
                    Kind::HostDown { node } => t.host_down(node),
                    Kind::SwToNic { node, nic } => t.sw_to_nic(node, nic),
                    Kind::NicToSw { node, nic } => t.nic_to_sw(node, nic),
                    Kind::NicUp { node, nic } => t.nic_up(node, nic),
                    Kind::NicDown { node, nic } => t.nic_down(node, nic),
                    Kind::LeafUp { leaf, spine } => t.leaf_up(leaf, spine),
                    Kind::SpineDown { spine, leaf } => t.spine_down(spine, leaf),
                    Kind::AggUp { leaf, agg } => t.agg_up(leaf, agg),
                    Kind::AggDown { pod, agg, leaf } => t.agg_down(pod, agg, leaf),
                    Kind::CoreUp { pod, core } => t.core_up(pod, core),
                    Kind::CoreDown { core, pod } => t.core_down(core, pod),
                    Kind::DfLocal { group, from, to } => t.df_local(group, from, to),
                    Kind::DfGlobal { from, to } => t.df_global(from, to),
                };
                if back != link {
                    return Err(format!(
                        "{fabric:?}/{nics}/{inter}: link {link} -> {kind:?} -> {back}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn rlft_dims_match_paper_for_both_sizes() {
    assert_eq!(presets::rlft_dims(32), (8, 4), "32 nodes: 8+4 = 12 switches");
    assert_eq!(presets::rlft_dims(128), (16, 8), "128 nodes: 16+8 = 24 switches");
}

/// SimConfig round-trip sanity used by the routing props: a fabric
/// config survives JSON and still builds the identical topology.
#[test]
fn fabric_config_roundtrip_builds_identical_topology() {
    for kind in FabricKind::ALL {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C3, 0.5);
        cfg.node.fabric = FabricConfig::new(kind, 2);
        let back = SimConfig::from_json_str(&cfg.to_json_string()).unwrap();
        let (a, b) = (Topology::new(&cfg), Topology::new(&back));
        assert_eq!(a.total_links(), b.total_links());
        assert_eq!(a.fabric, b.fabric);
        assert_eq!(a.nics_per_node, b.nics_per_node);
    }
}
