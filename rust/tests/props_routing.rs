//! Property tests: RLFT topology construction and D-mod-K routing
//! (DESIGN.md test inventory — routing properties).

use sauron::config::{presets, Pattern};
use sauron::net::{Kind, Topology};
use sauron::testkit::{forall, Choice, IntRange, Triple};

fn topo(nodes: usize) -> Topology {
    Topology::new(&presets::scaleout(nodes, 128.0, Pattern::C1, 0.5))
}

/// Walk a unit's full path from src accel to dst accel; return link kinds.
fn walk(t: &Topology, src: u32, dst: u32) -> Vec<Kind> {
    let node = t.accel_node(src);
    let local = t.accel_local(src);
    let mut link = t.accel_up(node, local);
    let mut kinds = vec![t.kind_of(link)];
    let mut hops = 0;
    while let Some(next) = t.next_hop(t.kind_of(link), dst) {
        link = next;
        kinds.push(t.kind_of(link));
        hops += 1;
        assert!(hops <= 16, "routing loop: {kinds:?}");
    }
    kinds
}

#[test]
fn prop_every_pair_delivers_within_8_hops() {
    let gen = Triple(
        Choice(&[32usize, 128]),
        IntRange { lo: 0, hi: 1023 },
        IntRange { lo: 0, hi: 1023 },
    );
    forall(0xA11CE, 400, &gen, |&(nodes, s, d)| {
        let t = topo(nodes);
        let total = t.total_accels() as u64;
        let (src, dst) = ((s % total) as u32, (d % total) as u32);
        if src == dst {
            return Ok(());
        }
        let kinds = walk(&t, src, dst);
        // Terminates at the destination accelerator's down-link.
        match *kinds.last().unwrap() {
            Kind::AccelDown { node, accel } => {
                if node != t.accel_node(dst) || accel != t.accel_local(dst) {
                    return Err(format!("delivered to wrong accel: {kinds:?}"));
                }
            }
            other => return Err(format!("path ends at {other:?}")),
        }
        if kinds.len() > 8 {
            return Err(format!("path too long ({}): {kinds:?}", kinds.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_intra_pairs_never_touch_the_nic() {
    let gen = Triple(Choice(&[32usize, 128]), IntRange { lo: 0, hi: 1023 }, IntRange { lo: 0, hi: 6 });
    forall(0xB0B, 300, &gen, |&(nodes, s, off)| {
        let t = topo(nodes);
        let total = t.total_accels() as u64;
        let src = (s % total) as u32;
        let node = t.accel_node(src);
        let a = t.accels_per_node as u64;
        let dst_local = (t.accel_local(src) as u64 + 1 + off) % a;
        let dst = node * t.accels_per_node + dst_local as u32;
        if dst == src {
            return Ok(());
        }
        let kinds = walk(&t, src, dst);
        if kinds.len() != 2 {
            return Err(format!("intra path must be 2 hops, got {kinds:?}"));
        }
        if kinds.iter().any(|k| {
            matches!(
                k,
                Kind::NicUp { .. } | Kind::NicDown { .. } | Kind::SwToNic { .. } | Kind::LeafUp { .. }
            )
        }) {
            return Err(format!("intra path crossed NIC: {kinds:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dmodk_spreads_destinations_evenly() {
    for nodes in [32usize, 128] {
        let t = topo(nodes);
        let mut counts = vec![0u32; t.spines as usize];
        for d in 0..t.nodes {
            counts[t.dmodk_spine(d) as usize] += 1;
        }
        let expect = (t.nodes / t.spines) as u32;
        assert!(counts.iter().all(|&c| c == expect), "{nodes} nodes: {counts:?}");
    }
}

#[test]
fn prop_same_destination_same_spine() {
    // D-mod-K: the spine serving a destination is source-independent ->
    // every destination has a unique down-path (contention-free ordering).
    let gen = Triple(
        Choice(&[32usize, 128]),
        IntRange { lo: 0, hi: 1023 },
        IntRange { lo: 0, hi: 1023 },
    );
    forall(0xD0D0, 300, &gen, |&(nodes, s1, s2)| {
        let t = topo(nodes);
        let total = t.total_accels() as u64;
        let dst = ((17 % t.nodes) * t.accels_per_node) as u32;
        let (a, b) = ((s1 % total) as u32, (s2 % total) as u32);
        let spine_of = |src: u32| -> Option<u32> {
            if t.accel_node(src) == t.accel_node(dst) {
                return None;
            }
            walk(&t, src, dst).iter().find_map(|k| match k {
                Kind::SpineDown { spine, .. } => Some(*spine),
                _ => None,
            })
        };
        match (spine_of(a), spine_of(b)) {
            (Some(x), Some(y)) if x != y => Err(format!("dst {dst}: spines {x} vs {y}")),
            _ => Ok(()),
        }
    });
}

#[test]
fn prop_link_ids_bijective() {
    let gen = Choice(&[2usize, 8, 32, 128]);
    forall(0x1D5, 20, &gen, |&nodes| {
        let t = topo(nodes);
        for link in 0..t.total_links() {
            let kind = t.kind_of(link);
            let back = match kind {
                Kind::AccelUp { node, accel } => t.accel_up(node, accel),
                Kind::AccelDown { node, accel } => t.accel_down(node, accel),
                Kind::SwToNic { node } => t.sw_to_nic(node),
                Kind::NicToSw { node } => t.nic_to_sw(node),
                Kind::NicUp { node } => t.nic_up(node),
                Kind::NicDown { node } => t.nic_down(node),
                Kind::LeafUp { leaf, spine } => t.leaf_up(leaf, spine),
                Kind::SpineDown { spine, leaf } => t.spine_down(spine, leaf),
            };
            if back != link {
                return Err(format!("link {link} -> {kind:?} -> {back}"));
            }
        }
        Ok(())
    });
}

#[test]
fn rlft_dims_match_paper_for_both_sizes() {
    assert_eq!(presets::rlft_dims(32), (8, 4), "32 nodes: 8+4 = 12 switches");
    assert_eq!(presets::rlft_dims(128), (16, 8), "128 nodes: 16+8 = 24 switches");
}
