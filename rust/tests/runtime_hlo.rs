//! Runtime integration: the AOT HLO artifacts executed through PJRT must
//! agree with the native analytic mirror, and a simulation fed by the HLO
//! provider must be *identical* to one fed by the native provider.
//!
//! These tests require `make artifacts` to have run (skipped with a clear
//! message otherwise).

use sauron::analytic::{CollParams, PcieParams};
use sauron::config::{presets, Pattern};
use sauron::net::world::{BenchMode, NativeProvider, SerProvider, Sim};
use sauron::runtime::Runtime;
use sauron::traffic::llm::{llm_traffic_native, LlmConfig};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts not available ({e:#}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn pcie_kernel_matches_native_mirror() {
    let Some(rt) = runtime() else { return };
    for p in [PcieParams::gen3(16), PcieParams::gen3(8), PcieParams::generic_accel_link(512.0)] {
        let sizes: Vec<u32> = (0..50).map(|i| 1 + i * 83_221).collect();
        let hlo = rt.pcie_latency_ns_exec(&p, &sizes).unwrap();
        for (s, h) in sizes.iter().zip(&hlo) {
            let native = p.latency_ns(*s as u64);
            let rel = ((h - native) / native).abs();
            assert!(rel < 1e-4, "size {s}: HLO {h} vs native {native}");
        }
    }
}

#[test]
fn pcie_kernel_handles_multi_batch_requests() {
    let Some(rt) = runtime() else { return };
    // 2500 sizes -> 3 executions of the 1024-wide artifact.
    let p = PcieParams::gen3(16);
    let sizes: Vec<u32> = (1..=2500).map(|i| i * 1000).collect();
    let hlo = rt.pcie_latency_ns_exec(&p, &sizes).unwrap();
    assert_eq!(hlo.len(), 2500);
    for (s, h) in sizes.iter().zip(&hlo).step_by(97) {
        let native = p.latency_ns(*s as u64);
        assert!(((h - native) / native).abs() < 1e-4);
    }
}

#[test]
fn collective_kernel_matches_native_mirror() {
    let Some(rt) = runtime() else { return };
    let cp = CollParams { n_devices: 8.0, alpha_ns: 700.0, beta_ns_per_b: 0.015 };
    let sizes: Vec<f32> = vec![1.0, 1e3, 1e6, 5e7];
    let rows = rt.collective_cost_exec(&cp, &sizes).unwrap();
    for (i, &s) in sizes.iter().enumerate() {
        let s = s as f64;
        for (row, want) in
            [(0, cp.allreduce_ns(s)), (1, cp.allgather_ns(s)), (2, cp.p2p_ns(s))]
        {
            let got = rows[row][i];
            assert!(((got - want) / want.max(1.0)).abs() < 1e-4, "row {row} size {s}: {got} vs {want}");
        }
    }
}

#[test]
fn llm_traffic_artifact_matches_native_mirror() {
    let Some(rt) = runtime() else { return };
    let pcie = PcieParams::gen3(16);
    let ci = CollParams { n_devices: 8.0, alpha_ns: 500.0, beta_ns_per_b: 0.002 };
    let cx = CollParams { n_devices: 8.0, alpha_ns: 2000.0, beta_ns_per_b: 0.02 };
    for llm in [
        LlmConfig::example_13b(),
        LlmConfig { tp: 1, pp: 8, ..LlmConfig::example_13b() },
        LlmConfig { tp: 8, pp: 1, dp: 1, ..LlmConfig::example_13b() },
    ] {
        let hlo = rt.llm_traffic(&llm, &pcie, &ci, &cx).unwrap();
        let nat = llm_traffic_native(&llm, &pcie, &ci, &cx);
        assert!((hlo.frac_inter - nat.frac_inter).abs() < 1e-4, "{llm:?}");
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
        assert!(rel(hlo.intra_bytes_per_step, nat.intra_bytes_per_step) < 1e-3);
        assert!(rel(hlo.dp_allreduce_ns, nat.dp_allreduce_ns) < 1e-3);
        assert!(rel(hlo.total_params, nat.total_params) < 1e-3);
        assert_eq!(hlo.nearest_paper_pattern(), nat.nearest_paper_pattern());
    }
}

#[test]
fn simulation_identical_under_hlo_and_native_providers() {
    let Some(rt) = runtime() else { return };
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, 0.4);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    let hlo = Sim::new(cfg.clone(), &rt, BenchMode::None).unwrap().run();
    let nat = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run();
    // f32 vs f64 rounding can shift a serialization by <=1 ps; with the
    // same seed the run should still be event-identical in practice.
    assert_eq!(hlo.delivered_msgs, nat.delivered_msgs);
    assert_eq!(hlo.events, nat.events);
    let rel = (hlo.intra_tput_gbs - nat.intra_tput_gbs).abs() / nat.intra_tput_gbs;
    assert!(rel < 1e-6, "throughput drifted: {rel}");
    assert_eq!(hlo.table_misses, 0);
}

#[test]
fn manifest_is_checked_on_load() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest.version, 1);
    assert_eq!(rt.manifest.pcie_latency.batch, 1024);
    assert_eq!(rt.manifest.collective_cost.batch, 256);
    assert_eq!(rt.manifest.llm_traffic.out_layout.len(), 16);
}

#[test]
fn provider_trait_through_runtime() {
    let Some(rt) = runtime() else { return };
    let p = PcieParams::generic_accel_link(128.0);
    let v = SerProvider::pcie_latency_ns(&rt, &p, &[4096, 4036, 60]);
    assert_eq!(v.len(), 3);
    assert!(v.iter().all(|x| *x > 0.0));
}
