//! Coordinator + reporting integration: tiny sweeps end-to-end through
//! the worker pool, CSV/JSON persistence, and figure-series grouping.

use std::sync::Arc;

use sauron::config::Pattern;
use sauron::coordinator::{self, results, SweepSpec};
use sauron::net::world::NativeProvider;
use sauron::report::figures::{self, FigureKind};

fn tiny() -> SweepSpec {
    SweepSpec {
        nodes: 32,
        intra_gbs: vec![128.0, 512.0],
        patterns: vec![Pattern::C1, Pattern::C5],
        loads: vec![0.2, 0.6],
        fabric: sauron::config::FabricConfig::switch_star(),
        inter: sauron::config::InterKind::LeafSpine,
        paper_windows: false,
        telemetry: false,
        workers: 2,
        seed: 0xFEED,
        faults: Default::default(),
        limits: Default::default(),
        shards: 1,
    }
}

#[test]
fn sweep_to_figures_pipeline() {
    let spec = tiny();
    let provider = Arc::new(coordinator::snapshot_provider(&spec, &NativeProvider));
    let reports = coordinator::run_sweep(&spec, provider.clone(), None).unwrap();
    assert_eq!(reports.len(), 8);
    assert_eq!(provider.miss_count(), 0, "sweep must be fully table-driven");

    // Figure grouping: 2 subfigures (bandwidths) x 2 series (patterns) x 2 loads.
    let figs = figures::figure_series(&reports, FigureKind::IntraThroughput);
    assert_eq!(figs.len(), 2);
    for sf in &figs {
        assert_eq!(sf.series.len(), 2);
        for s in &sf.series {
            assert_eq!(s.loads, vec![0.2, 0.6]);
        }
    }
    // C5 has no FCT series values > 0.
    let fct = figures::figure_series(&reports, FigureKind::Fct);
    let c5 = fct[0].series.iter().find(|s| s.pattern == "C5").unwrap();
    assert!(c5.values.iter().all(|&v| v == 0.0));
}

#[test]
fn csv_and_json_roundtrip() {
    let spec = SweepSpec { loads: vec![0.3], patterns: vec![Pattern::C3], intra_gbs: vec![128.0], ..tiny() };
    let provider = Arc::new(coordinator::snapshot_provider(&spec, &NativeProvider));
    let reports = coordinator::run_sweep(&spec, provider, None).unwrap();

    let dir = std::env::temp_dir().join("sauron_sweep_int_test");
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("sweep.csv");
    let json_path = dir.join("sweep.json");
    results::write_csv(&csv_path, &reports).unwrap();
    results::write_json(&json_path, &reports).unwrap();

    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert_eq!(csv.lines().count(), 2);
    assert!(csv.lines().nth(1).unwrap().starts_with("C3,0.3"));

    let back = results::read_json(&json_path).unwrap();
    assert_eq!(back.len(), reports.len());
    assert_eq!(back[0].pattern, "C3");
    assert_eq!(back[0].delivered_msgs, reports[0].delivered_msgs);
    assert_eq!(back[0].fct.p99_ns, reports[0].fct.p99_ns);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn render_figures_contains_all_series() {
    let spec = tiny();
    let provider = Arc::new(coordinator::snapshot_provider(&spec, &NativeProvider));
    let reports = coordinator::run_sweep(&spec, provider, None).unwrap();
    for kind in [
        FigureKind::IntraThroughput,
        FigureKind::IntraLatency,
        FigureKind::InterThroughput,
        FigureKind::Fct,
    ] {
        let txt = figures::render_figure(&reports, kind);
        assert!(txt.contains("C1") && txt.contains("C5"), "{kind:?}: {txt}");
        assert!(txt.contains("128") && txt.contains("512"));
    }
}

#[test]
fn paper_spec_enumerates_full_grid() {
    for nodes in [32, 128] {
        let spec = SweepSpec::paper(nodes);
        assert_eq!(spec.points(), 300);
        let cfgs = spec.configs();
        // all loads in (0, 1], all patterns present, seeds unique
        assert!(cfgs.iter().all(|c| c.traffic.load > 0.0 && c.traffic.load <= 1.0));
        let mut seeds: Vec<u64> = cfgs.iter().map(|c| c.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 300);
    }
}
