//! Telemetry invariants (ISSUE 5 tentpole): the flow-class telemetry
//! subsystem must be **strictly observational**.
//!
//! Properties:
//!
//! 1. **Toggle invisibility** — a run with `telemetry.enabled` produces
//!    a `SimReport` bit-identical on every pre-existing field (event
//!    count included) to the same run with it off, across all four
//!    fabrics, NIC counts/policies and workload kinds (the generator is
//!    the `props_reuse.rs` one).
//! 2. **Byte conservation** — on every reported link, per-class wire
//!    bytes sum exactly to the link's total (`LinkStat::wire_bytes`),
//!    and the utilization bins partition the same total; on a fully
//!    drained open-loop run, per-class delivered payload sums to
//!    `completed messages × message size`.
//! 3. **No phantom blocking** — an uncongested single-class run records
//!    zero head-of-line blocking and touches no other class.
//! 4. **Interference is visible where the paper says it is** — under
//!    inter-node background traffic congesting the receive path, the
//!    NIC down-links record nonzero head-of-line blocking (the
//!    acceptance anchor for the mesh-vs-star attribution example).
//! 5. **Reset reuse** — telemetry is a run-phase delta: a reused world
//!    toggling it between points reproduces a fresh build's link stats
//!    exactly, and the report round-trips through JSON.

use std::sync::Arc;

use sauron::config::{
    presets, CollOp, CollScope, CollectiveSpec, FabricConfig, FabricKind, NicPolicy, Pattern,
    SimConfig, Workload,
};
use sauron::metrics::TrafficClass;
use sauron::net::world::{BenchMode, NativeProvider, Sim, SimReport, WorldBlueprint};
use sauron::serial::json::{FromJson, ToJson};
use sauron::testkit::{forall, Choice, FloatRange, Triple};
use sauron::units::Time;

/// Compare every pre-telemetry result field; `wall_ms` and the new
/// `link_stats` / `telemetry_bin_ps` are excluded by construction.
fn pre_existing_identical(on: &SimReport, off: &SimReport) -> Result<(), String> {
    macro_rules! field_eq {
        ($field:ident) => {
            if on.$field != off.$field {
                return Err(format!(
                    "field {} differs: {:?} (telemetry on) vs {:?} (off)",
                    stringify!($field),
                    on.$field,
                    off.$field
                ));
            }
        };
    }
    field_eq!(pattern);
    field_eq!(load);
    field_eq!(nodes);
    field_eq!(accels);
    field_eq!(fabric);
    field_eq!(nics);
    field_eq!(aggregated_intra_gbs);
    field_eq!(offered_gbs);
    field_eq!(intra_tput_gbs);
    field_eq!(intra_drain_gbs);
    field_eq!(intra_lat);
    field_eq!(inter_tput_gbs);
    field_eq!(inter_drain_gbs);
    field_eq!(fct);
    field_eq!(intra_wire_gbs);
    field_eq!(inter_wire_gbs);
    field_eq!(drop_frac);
    field_eq!(delivered_msgs);
    field_eq!(offered_msgs);
    field_eq!(events);
    field_eq!(table_misses);
    field_eq!(coll_op);
    field_eq!(coll_size_b);
    field_eq!(coll_iters);
    field_eq!(coll_time);
    field_eq!(coll_pred_ns);
    Ok(())
}

/// Per-link conservation: class bytes and bins both partition the
/// link's total wire bytes.
fn link_stats_conserve(r: &SimReport) -> Result<(), String> {
    if r.link_stats.is_empty() {
        return Err("telemetry run reported no link activity".into());
    }
    if r.telemetry_bin_ps == 0 {
        return Err("telemetry run reported no bin width".into());
    }
    for s in &r.link_stats {
        let class_sum: u64 = s.class_bytes.iter().sum();
        if class_sum != s.wire_bytes {
            return Err(format!(
                "link {} ({}): class bytes {class_sum} != wire total {}",
                s.link, s.detail, s.wire_bytes
            ));
        }
        let bin_sum: u64 = s.util_bins.iter().flatten().sum();
        if bin_sum != s.wire_bytes {
            return Err(format!(
                "link {} ({}): binned bytes {bin_sum} != wire total {}",
                s.link, s.detail, s.wire_bytes
            ));
        }
    }
    Ok(())
}

/// Run `cfg` twice — telemetry off and on — and hold both the toggle
/// invisibility and the conservation invariants.
fn check_toggle(mut cfg: SimConfig) -> Result<(), String> {
    cfg.telemetry.enabled = false;
    let off = Sim::new(cfg.clone(), &NativeProvider, BenchMode::None)
        .map_err(|e| format!("build (off): {e:#}"))?
        .try_run()
        .map_err(|e| format!("run (off): {e:#}"))?;
    cfg.telemetry.enabled = true;
    let on = Sim::new(cfg, &NativeProvider, BenchMode::None)
        .map_err(|e| format!("build (on): {e:#}"))?
        .try_run()
        .map_err(|e| format!("run (on): {e:#}"))?;
    if !off.link_stats.is_empty() {
        return Err("telemetry-off report carried link stats".into());
    }
    pre_existing_identical(&on, &off)?;
    link_stats_conserve(&on)
}

fn fabric_cfg(
    kind: FabricKind,
    nics: usize,
    policy: NicPolicy,
    load: f64,
    pattern: Pattern,
) -> SimConfig {
    let mut fab = FabricConfig::new(kind, nics);
    fab.nic_policy = policy;
    let mut cfg = presets::with_fabric(presets::scaleout(32, 256.0, pattern, load), fab);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    cfg.seed = 0x7E1E;
    cfg
}

#[test]
fn prop_toggle_invisible_across_fabrics_and_policies() {
    // Load capped below saturation (the ring fabric's diagnosed
    // credit-cycle deadlock is a legitimate outcome but not a report).
    let gen = Triple(
        Choice(&FabricKind::ALL),
        Choice(&[
            (1usize, NicPolicy::LocalRank),
            (2, NicPolicy::LocalRank),
            (2, NicPolicy::RoundRobin),
            (4, NicPolicy::RoundRobin),
        ]),
        FloatRange { lo: 0.05, hi: 0.45 },
    );
    forall(0x7E1EA, 10, &gen, |&(kind, (nics, policy), load)| {
        check_toggle(fabric_cfg(kind, nics, policy, load, Pattern::C2))
            .map_err(|e| format!("{kind:?}/{nics}nic/{policy:?}/{load:.3}: {e}"))
    });
}

#[test]
fn prop_toggle_invisible_for_collectives() {
    let gen = Triple(
        Choice(&[
            CollOp::RingAllReduce,
            CollOp::ReduceScatter,
            CollOp::AllGather,
            CollOp::AllToAll,
            CollOp::HierarchicalAllReduce,
        ]),
        Choice(&[32u64 * 1024, 128 * 1024]),
        Choice(&[0.0f64, 0.25]),
    );
    forall(0x7E1EB, 8, &gen, |&(op, size_b, bg_load)| {
        let scope = if op == CollOp::HierarchicalAllReduce {
            CollScope::Global
        } else {
            CollScope::PerNode
        };
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, bg_load);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 15.0;
        cfg.seed = 0xC0FFEE;
        cfg.workload = Workload::Collective(CollectiveSpec { op, scope, size_b, iters: 2 });
        check_toggle(cfg).map_err(|e| format!("{op:?}/{size_b}/{bg_load}: {e}"))
    });
}

#[test]
fn toggle_invisible_for_bench_drivers() {
    for (bench, sizes) in [
        (BenchMode::PingPong { a: 0, b: 17, size_b: 4096 }, vec![4096u32]),
        (BenchMode::Window { src: 0, dst: 9, size_b: 1 << 16, inflight: 4 }, vec![1u32 << 16]),
    ] {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C5, 0.0);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 20.0;
        let off = Sim::with_extra_sizes(cfg.clone(), &NativeProvider, bench, &sizes)
            .unwrap()
            .try_run()
            .unwrap();
        cfg.telemetry.enabled = true;
        let on = Sim::with_extra_sizes(cfg, &NativeProvider, bench, &sizes)
            .unwrap()
            .try_run()
            .unwrap();
        pre_existing_identical(&on, &off).unwrap_or_else(|e| panic!("{bench:?}: {e}"));
        link_stats_conserve(&on).unwrap_or_else(|e| panic!("{bench:?}: {e}"));
        // Bench traffic is the only class on the wire.
        for s in &on.link_stats {
            assert_eq!(
                s.class_bytes[TrafficClass::Bench.idx()],
                s.wire_bytes,
                "{bench:?}: {} carried a non-bench class",
                s.detail
            );
        }
    }
}

#[test]
fn delivered_bytes_conserved_on_drained_open_loop_run() {
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C2, 0.3);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    cfg.telemetry.enabled = true;
    let msg_size = cfg.traffic.msg_size_b;
    let mut sim = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap();
    let end = sim.world().end_time();
    sim.engine_mut().run_until(end);
    sim.engine_mut().run_until(Time::MAX); // generators stopped; drain
    let w = sim.world();
    assert_eq!(w.injected_msgs, w.completed_msgs, "sanity: the run drained");
    assert!(w.completed_msgs > 100, "sanity: traffic flowed");
    let t = w.telemetry().expect("telemetry enabled");
    let delivered: u64 = t.delivered_bytes().iter().sum();
    assert_eq!(
        delivered,
        w.completed_msgs * msg_size,
        "per-class delivered payload must sum to total delivered volume"
    );
    // Only the two open-loop classes exist in this run.
    assert_eq!(t.delivered_bytes()[TrafficClass::CollectiveIntra.idx()], 0);
    assert_eq!(t.delivered_bytes()[TrafficClass::CollectiveInter.idx()], 0);
    assert_eq!(t.delivered_bytes()[TrafficClass::Bench.idx()], 0);
    assert!(t.delivered_bytes()[TrafficClass::IntraLocal.idx()] > 0);
    assert!(t.delivered_bytes()[TrafficClass::InterBackground.idx()] > 0);
}

#[test]
fn uncongested_single_class_run_records_no_blocking() {
    // C5 = intra only; 5% load saturates nothing.
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C5, 0.05);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 15.0;
    cfg.telemetry.enabled = true;
    let r = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run();
    link_stats_conserve(&r).unwrap();
    for s in &r.link_stats {
        assert_eq!(
            s.hol_total_ps(),
            0,
            "{}: uncongested single-class run must record zero HoL blocking",
            s.detail
        );
        assert_eq!(
            s.class_bytes[TrafficClass::IntraLocal.idx()],
            s.wire_bytes,
            "{}: only the intra_local class may appear",
            s.detail
        );
    }
}

#[test]
fn receive_congestion_shows_hol_blocking_on_nic_down_links() {
    // Deterministic receive-path congestion: a Window bench streams the
    // full 400 Gbps NIC rate into one destination accelerator whose
    // down-link runs at ~128 Gbps. With the receive-side buffers
    // shrunk, the ingress chain (nic_to_sw, then the NIC down-link's
    // port buffer) must fill and the upstream link parks on the NIC
    // down-link — the paper's "arriving inter traffic backs up into the
    // intra network", recorded as head-of-line blocking on `nic_down`.
    let mut cfg = presets::scaleout(32, 128.0, Pattern::C5, 0.0);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 30.0;
    cfg.node.nic.ingress_buf_b = 16 * 1024;
    cfg.inter.port_buf_b = 8 * 1024;
    cfg.telemetry.enabled = true;
    let bench = BenchMode::Window { src: 0, dst: 8, size_b: 1 << 16, inflight: 4 };
    let r = Sim::with_extra_sizes(cfg, &NativeProvider, bench, &[1 << 16])
        .unwrap()
        .try_run()
        .unwrap();
    link_stats_conserve(&r).unwrap();
    let nic_down_hol: u64 = r
        .link_stats
        .iter()
        .filter(|s| s.kind == "nic_down")
        .map(|s| s.hol_total_ps())
        .sum();
    assert!(
        nic_down_hol > 0,
        "sustained receive overload must record HoL blocking on nic_down links"
    );
}

#[test]
fn background_inter_saturation_blocks_on_nic_down_links() {
    // The acceptance anchor in open-loop form: all-inter background
    // traffic at full load saturates every NIC boundary; the receive
    // chain behind each NIC down-link runs at utilization ~1 and its
    // (shrunken) buffers fill, so the fat-tree's last hop parks —
    // nonzero HoL blocking on NIC down-links, attributed to the
    // inter_background class on both sides.
    let mut cfg = presets::scaleout(32, 128.0, Pattern::Custom { frac_inter: 1.0 }, 1.0);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 30.0;
    cfg.node.nic.ingress_buf_b = 16 * 1024;
    cfg.inter.port_buf_b = 8 * 1024;
    cfg.telemetry.enabled = true;
    let r = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().try_run().unwrap();
    link_stats_conserve(&r).unwrap();
    let blocked_bg: u64 = r
        .link_stats
        .iter()
        .filter(|s| s.kind == "nic_down")
        .map(|s| s.hol_blocked_ps(TrafficClass::InterBackground))
        .sum();
    assert!(
        blocked_bg > 0,
        "background inter traffic at saturation must show HoL blocking on nic_down"
    );
}

#[test]
fn interference_preset_attributes_collective_blocking() {
    // The mesh-vs-star worked example's star arm (1 MiB hierarchical
    // AllReduce vs all-inter background), shrunk for test budgets: the
    // collective classes must appear on the NIC-boundary links and be
    // measurably blocked somewhere on the path.
    let mut cfg =
        presets::fabric_interference(FabricKind::SwitchStar, 1, 32, 256.0, 256 * 1024, 0.35);
    cfg.warmup_us = 10.0;
    cfg.measure_us = 100.0;
    cfg.telemetry.enabled = true;
    let r = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().try_run().unwrap();
    link_stats_conserve(&r).unwrap();
    assert_eq!(r.coll_iters, 2, "sanity: the collective completed");
    let nic_up_coll: u64 = r
        .link_stats
        .iter()
        .filter(|s| s.kind == "nic_up")
        .map(|s| s.class_bytes[TrafficClass::CollectiveInter.idx()])
        .sum();
    assert!(nic_up_coll > 0, "the inter exchange must cross the NIC up-links");
    let total_hol: u64 = r.link_stats.iter().map(|s| s.hol_total_ps()).sum();
    assert!(total_hol > 0, "an oversubscribed NIC boundary must record HoL blocking");
    let coll_blocked: u64 = r
        .link_stats
        .iter()
        .map(|s| {
            s.hol_blocked_ps(TrafficClass::CollectiveInter)
                + s.hol_blocked_ps(TrafficClass::CollectiveIntra)
        })
        .sum();
    assert!(
        coll_blocked > 0,
        "collective traffic must be measurably blocked under background load"
    );
}

#[test]
fn telemetry_is_a_run_phase_delta_and_reuse_matches_fresh() {
    let point = |seed: u64, load: f64, telemetry: bool| {
        let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, load);
        cfg.warmup_us = 5.0;
        cfg.measure_us = 10.0;
        cfg.seed = seed;
        cfg.telemetry.enabled = telemetry;
        cfg
    };
    let bp = Arc::new(
        WorldBlueprint::compile(point(1, 0.2, false), &NativeProvider, BenchMode::None, &[])
            .unwrap(),
    );
    let mut sim = Sim::from_blueprint(&bp, point(1, 0.2, false)).unwrap();
    let first = sim.try_run_mut().unwrap();
    assert!(first.link_stats.is_empty());
    // Toggle telemetry ON across a reset: a run-phase delta.
    sim.reset(point(9, 0.4, true)).unwrap();
    let reused = sim.try_run_mut().unwrap();
    let fresh = Sim::new(point(9, 0.4, true), &NativeProvider, BenchMode::None)
        .unwrap()
        .try_run()
        .unwrap();
    pre_existing_identical(&reused, &fresh).unwrap();
    assert_eq!(reused.telemetry_bin_ps, fresh.telemetry_bin_ps);
    assert_eq!(reused.link_stats, fresh.link_stats, "reused telemetry must match fresh");
    link_stats_conserve(&reused).unwrap();
    // And OFF again: the stats disappear, results unchanged vs fresh.
    sim.reset(point(9, 0.4, false)).unwrap();
    let off = sim.try_run_mut().unwrap();
    assert!(off.link_stats.is_empty());
    pre_existing_identical(&reused, &off).unwrap();
}

#[test]
fn telemetry_report_roundtrips_json() {
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, 0.4);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    cfg.telemetry.enabled = true;
    let r = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run();
    assert!(!r.link_stats.is_empty());
    let back = SimReport::from_json(&r.to_json()).unwrap();
    assert_eq!(back.link_stats, r.link_stats);
    assert_eq!(back.telemetry_bin_ps, r.telemetry_bin_ps);
    pre_existing_identical(&back, &r).unwrap();
}
