//! Closed-loop collective workloads end-to-end through the world engine:
//! the sim-vs-analytic oracle on an uncongested intra-node ring, and the
//! paper's qualitative interference trend — with concurrent inter-node
//! background traffic, raising intra-node bandwidth does not improve
//! (and eventually degrades) hierarchical-AllReduce completion time,
//! because offered background load scales with the intra links while the
//! NIC boundary stays fixed.

use sauron::analytic::CollParams;
use sauron::config::{
    presets, CollOp, CollScope, CollectiveSpec, FabricKind, FaultAction, FaultEvent, FaultPlan,
    LinkSel, Pattern, TelemetryConfig, Workload,
};
use sauron::net::world::{BenchMode, NativeProvider, Sim};
use sauron::report::figures;

const MIB: u64 = 1 << 20;

fn run_collective(
    nodes: usize,
    gbs: f64,
    spec: CollectiveSpec,
    bg_pattern: Pattern,
    bg_load: f64,
) -> sauron::SimReport {
    let cfg = presets::collective_scaleout(nodes, gbs, spec, bg_pattern, bg_load);
    Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run()
}

/// Satellite oracle: simulated ring AllReduce on an uncongested
/// single-node group agrees with `CollParams::ring_allreduce` (α-β over
/// the PCIe chunk cost) within 5%.
#[test]
fn ring_allreduce_matches_analytic_oracle_within_5pct() {
    let spec = CollectiveSpec {
        op: CollOp::RingAllReduce,
        scope: CollScope::PerNode,
        size_b: MIB,
        iters: 3,
    };
    for gbs in [128.0, 256.0, 512.0] {
        let cfg = presets::collective_scaleout(32, gbs, spec, Pattern::C5, 0.0);
        let accels = cfg.node.accels_per_node as u32;
        let chunk = spec.size_b / accels as u64;
        let oracle = CollParams::from_pcie(&cfg.node.accel_link, accels, chunk)
            .ring_allreduce_ns(spec.size_b as f64);
        let r = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run();
        assert_eq!(r.coll_iters, 3);
        let rel = (r.coll_time.mean_ns - oracle).abs() / oracle;
        assert!(
            rel < 0.05,
            "{gbs} GB/s: sim {:.1} ns vs oracle {oracle:.1} ns ({:.1}%)",
            r.coll_time.mean_ns,
            rel * 100.0
        );
        // The report's built-in prediction is the same oracle.
        let rel_report = (r.coll_pred_ns - oracle).abs() / oracle;
        assert!(rel_report < 1e-9, "report pred {} vs {oracle}", r.coll_pred_ns);
    }
}

/// Uncongested hierarchical AllReduce benefits from intra bandwidth: the
/// intra reduce/broadcast phases dominate and speed up 128→512 GB/s.
#[test]
fn hierarchical_uncongested_improves_with_intra_bandwidth() {
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: MIB,
        iters: 2,
    };
    let t128 = run_collective(32, 128.0, spec, Pattern::C5, 0.0).coll_time.mean_ns;
    let t512 = run_collective(32, 512.0, spec, Pattern::C5, 0.0).coll_time.mean_ns;
    assert!(
        t512 < 0.7 * t128,
        "512 GB/s should beat 128 GB/s uncongested: {t512:.0} vs {t128:.0} ns"
    );
    // The composed analytic prediction tracks the same order of magnitude
    // (sanity for the NIC-boundary pipeline model; the strict 5% oracle
    // is the per-node ring above).
    let r = run_collective(32, 128.0, spec, Pattern::C5, 0.0);
    assert!(r.coll_pred_ns > 0.0);
    let ratio = r.coll_time.mean_ns / r.coll_pred_ns;
    assert!((0.3..3.0).contains(&ratio), "sim/pred ratio {ratio:.2}");
}

/// Acceptance trend: against concurrent inter-node background traffic,
/// raising intra-node bandwidth does not improve hierarchical-AllReduce
/// completion — the background offered load grows with the intra links
/// (load is a fraction of link capacity), over-subscribing the fixed
/// 400 Gbps NIC and stalling the inter-exchange phase.
#[test]
fn hierarchical_congested_does_not_improve_with_intra_bandwidth() {
    // One iteration, and a measure window long enough that the background
    // generators stay live for the whole collective at every bandwidth.
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 1,
    };
    let bg = Pattern::Custom { frac_inter: 1.0 };
    let load = 0.35; // offered inter per node: 128 GB/s -> ~358 Gbps
                     // (below the 400 Gbps NIC); 256 -> ~717; 512 ->
                     // ~1434 — far past it, so the inter-exchange phase
                     // stalls behind background backlogs.
    let run = |gbs: f64, pattern: Pattern, load: f64| {
        let mut cfg = presets::collective_scaleout(32, gbs, spec, pattern, load);
        cfg.measure_us = 500.0;
        Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run().coll_time.mean_ns
    };
    let t128 = run(128.0, bg, load);
    let t256 = run(256.0, bg, load);
    let t512 = run(512.0, bg, load);
    assert!(
        t512 >= 0.95 * t128,
        "raising intra bandwidth must not improve congested completion: \
         128 -> {t128:.0} ns, 256 -> {t256:.0} ns, 512 -> {t512:.0} ns"
    );
    assert!(
        t512.max(t256) >= t128,
        "trend: saturation at higher intra bandwidth should dominate: \
         128 -> {t128:.0} ns, 256 -> {t256:.0} ns, 512 -> {t512:.0} ns"
    );
    // And congestion must actually hurt at 512 vs its own uncongested run.
    let t512_clean = run(512.0, Pattern::C5, 0.0);
    assert!(
        t512 > 1.2 * t512_clean,
        "background traffic should degrade 512 GB/s completion: \
         {t512:.0} vs clean {t512_clean:.0} ns"
    );
}

/// The congested trend is a property of the NIC boundary, not of the
/// inter-node wiring: it must hold unchanged on every pluggable inter
/// topology (leaf/spine, 3-level fat tree, dragonfly).
#[test]
fn hierarchical_congested_trend_holds_on_every_inter_kind() {
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 1,
    };
    for inter in ["leaf_spine", "fat_tree3", "dragonfly"] {
        let run = |gbs: f64| {
            let mut cfg = presets::collective_scaleout(
                32,
                gbs,
                spec,
                Pattern::Custom { frac_inter: 1.0 },
                0.35,
            );
            cfg.inter.kind =
                presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
            cfg.measure_us = 500.0;
            Sim::new(cfg, &NativeProvider, BenchMode::None)
                .unwrap_or_else(|e| panic!("{inter}/{gbs}: {e:#}"))
                .try_run()
                .unwrap_or_else(|e| panic!("{inter}/{gbs}: {e:#}"))
                .coll_time
                .mean_ns
        };
        let t128 = run(128.0);
        let t512 = run(512.0);
        assert!(
            t512 >= 0.95 * t128,
            "{inter}: raising intra bandwidth must not improve congested completion: \
             128 -> {t128:.0} ns, 512 -> {t512:.0} ns"
        );
    }
}

/// Acceptance (post-exascale scale): a 1024-node hierarchical AllReduce
/// under all-inter background traffic completes on the 3-level fat tree
/// AND the dragonfly, and the PR-5 interference-attribution CSV names
/// the inter *levels* the traffic lands on (`agg_*`/`core_*`,
/// `df_local`/`df_global`) — the per-level view the 2-level leaf/spine
/// could never produce. Background generators stop at the (short)
/// window end, so the collective drains to completion cheaply.
#[test]
fn post_exascale_fat_tree_and_dragonfly_attribute_inter_levels() {
    for (inter, levels) in [
        ("fat_tree3", &["agg_up", "agg_down", "core_up", "core_down"][..]),
        ("dragonfly", &["df_local", "df_global"][..]),
    ] {
        let spec = CollectiveSpec {
            op: CollOp::HierarchicalAllReduce,
            scope: CollScope::Global,
            size_b: 32 * 1024,
            iters: 1,
        };
        let mut cfg = presets::collective_scaleout(
            1024,
            256.0,
            spec,
            Pattern::Custom { frac_inter: 1.0 },
            0.3,
        );
        cfg.inter.kind = presets::default_inter_kind(inter, cfg.inter.leaves, cfg.inter.spines);
        cfg.node.accels_per_node = 2; // 2048 ranks keep the run tractable
        cfg.warmup_us = 2.0;
        cfg.measure_us = 20.0;
        cfg.telemetry = TelemetryConfig { enabled: true, bins: 8 };
        let r = Sim::new(cfg, &NativeProvider, BenchMode::None)
            .unwrap_or_else(|e| panic!("{inter}: {e:#}"))
            .try_run()
            .unwrap_or_else(|e| panic!("{inter}: {e:#}"));
        assert_eq!(r.nodes, 1024, "{inter}");
        assert_eq!(r.inter, inter);
        assert_eq!(r.coll_iters, 1, "{inter}: collective must complete");
        assert!(r.coll_time.mean_ns > 0.0, "{inter}");
        let csv = figures::link_attribution_csv(&r);
        for level in levels {
            assert!(
                csv.lines().any(|l| l.split(',').nth(1) == Some(*level)),
                "{inter}: attribution CSV must carry {level} rows"
            );
        }
    }
}

/// The EXPERIMENTS.md graceful-degradation story, asserted: killing the
/// leaf-0 → spine-0 trunk mid-run must not stop the congested
/// hierarchical AllReduce — routing re-steers onto the three surviving
/// up-trunks, which therefore carry strictly more wire bytes (and at
/// least as much head-of-line blocking) than in the healthy arm, while
/// the dead trunk stops accumulating and gets its downtime accounted.
#[test]
fn dead_trunk_shifts_hol_blocking_onto_surviving_rails() {
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 2,
    };
    let mut cfg =
        presets::collective_scaleout(32, 256.0, spec, Pattern::Custom { frac_inter: 1.0 }, 0.35);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 25.0;
    cfg.telemetry = TelemetryConfig { enabled: true, bins: 8 };
    let mut faulty_cfg = cfg.clone();
    faulty_cfg.faults = FaultPlan {
        events: vec![FaultEvent {
            at_us: 8.0,
            action: FaultAction::LinkDown,
            sel: Some(LinkSel::LeafUp { leaf: 0, spine: 0 }),
        }],
    };
    let healthy = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run();
    let faulty =
        Sim::new(faulty_cfg, &NativeProvider, BenchMode::None).unwrap().try_run().unwrap();
    assert_eq!(faulty.coll_iters, 2, "collective must complete around the dead trunk");

    // leaf-0 up-trunk stats by spine index, in both arms.
    let trunk = |r: &sauron::SimReport, spine: usize| {
        r.link_stats
            .iter()
            .find(|s| s.detail == format!("leaf_up[l0->s{spine}]"))
            .cloned()
            .unwrap_or_else(|| panic!("no leaf_up[l0->s{spine}] stat"))
    };
    let dead = trunk(&faulty, 0);
    assert!(dead.fault_ps > 0, "dead trunk must account its downtime");
    assert!(
        dead.wire_bytes < trunk(&healthy, 0).wire_bytes,
        "dead trunk must stop accumulating: {} vs healthy {}",
        dead.wire_bytes,
        trunk(&healthy, 0).wire_bytes
    );
    let survivors =
        |r: &sauron::SimReport, f: &dyn Fn(&sauron::metrics::LinkStat) -> u64| -> u64 {
            (1..4).map(|s| f(&trunk(r, s))).sum()
        };
    let bytes_faulty = survivors(&faulty, &|s| s.wire_bytes);
    let bytes_healthy = survivors(&healthy, &|s| s.wire_bytes);
    assert!(
        bytes_faulty > bytes_healthy,
        "surviving rails must absorb the re-steered share: {bytes_faulty} vs {bytes_healthy}"
    );
    let hol_faulty = survivors(&faulty, &|s| s.hol_total_ps());
    let hol_healthy = survivors(&healthy, &|s| s.hol_total_ps());
    assert!(
        hol_faulty >= hol_healthy,
        "blocking must shift toward the surviving rails: {hol_faulty} vs {hol_healthy}"
    );
}

/// Acceptance: one preset per intra fabric runs the hierarchical-
/// AllReduce experiment end-to-end uncongested, and the per-fabric
/// analytic oracle tracks the simulation within tolerance. The star's
/// pipeline model historically lands within 3x; the mesh/ring
/// single-hop oracles are at least as tight; the host tree's shared-
/// bridge bound is the roughest and gets the widest band.
#[test]
fn every_fabric_hierarchical_matches_its_oracle_within_tolerance() {
    for (kind, nics, lo, hi) in [
        (FabricKind::SwitchStar, 1usize, 0.3, 3.0),
        (FabricKind::Mesh, 4, 0.3, 3.0),
        (FabricKind::Ring, 2, 0.3, 3.0),
        (FabricKind::HostTree, 1, 0.2, 5.0),
    ] {
        let cfg = presets::fabric_interference(kind, nics, 32, 256.0, 256 * 1024, 0.0);
        let r = Sim::new(cfg, &NativeProvider, BenchMode::None)
            .unwrap_or_else(|e| panic!("{kind:?}: {e:#}"))
            .try_run()
            .unwrap_or_else(|e| panic!("{kind:?}: {e:#}"));
        assert_eq!(r.coll_iters, 2, "{kind:?}");
        assert_eq!(r.fabric, kind.name());
        assert_eq!(r.nics, nics);
        assert!(r.coll_pred_ns > 0.0, "{kind:?}: oracle missing");
        let ratio = r.coll_time.mean_ns / r.coll_pred_ns;
        assert!(
            (lo..hi).contains(&ratio),
            "{kind:?}/{nics} NIC: sim {:.0} ns vs oracle {:.0} ns (ratio {ratio:.2}, \
             tolerance {lo}..{hi})",
            r.coll_time.mean_ns,
            r.coll_pred_ns
        );
    }
}

/// Acceptance: the same presets survive the *interference* experiment —
/// hierarchical AllReduce against all-inter background traffic — end to
/// end on every fabric, and congestion never speeds the collective up.
#[test]
fn every_fabric_interference_runs_end_to_end() {
    for cfg in presets::fabric_family(32, 256.0, 0.2) {
        let kind = cfg.node.fabric.kind;
        let mut clean = cfg.clone();
        clean.traffic.load = 0.0;
        let clean_ns = Sim::new(clean, &NativeProvider, BenchMode::None)
            .unwrap()
            .try_run()
            .unwrap_or_else(|e| panic!("{kind:?} clean: {e:#}"))
            .coll_time
            .mean_ns;
        let congested = Sim::new(cfg, &NativeProvider, BenchMode::None)
            .unwrap()
            .try_run()
            .unwrap_or_else(|e| panic!("{kind:?} congested: {e:#}"));
        assert_eq!(congested.coll_iters, 2, "{kind:?}");
        assert!(
            congested.coll_time.mean_ns >= clean_ns * 0.99,
            "{kind:?}: background traffic sped the collective up?! \
             {:.0} vs clean {clean_ns:.0} ns",
            congested.coll_time.mean_ns
        );
    }
}

/// Multi-NIC payoff: on the star fabric, the congested hierarchical
/// AllReduce completes faster with 4 NICs than with 1 — the follow-up
/// paper's motivation for opening the NIC-count axis.
#[test]
fn more_nics_relieve_the_interference_bottleneck() {
    let run = |nics: usize| {
        let cfg =
            presets::fabric_interference(FabricKind::SwitchStar, nics, 32, 256.0, 256 * 1024, 0.3);
        Sim::new(cfg, &NativeProvider, BenchMode::None)
            .unwrap()
            .try_run()
            .unwrap_or_else(|e| panic!("{nics} NICs: {e:#}"))
            .coll_time
            .mean_ns
    };
    let one = run(1);
    let four = run(4);
    assert!(
        four < one,
        "4 NICs must beat 1 NIC under NIC-boundary congestion: {four:.0} vs {one:.0} ns"
    );
}

/// Mesh-vs-star interference (the worked example in EXPERIMENTS.md):
/// uncongested, the mesh's single-hop intra phases beat the star's
/// two-hop phases at equal per-lane bandwidth.
#[test]
fn mesh_uncongested_beats_star_on_intra_phases() {
    let run = |kind: FabricKind, nics: usize| {
        let cfg = presets::fabric_interference(kind, nics, 32, 256.0, 1 << 20, 0.0);
        Sim::new(cfg, &NativeProvider, BenchMode::None)
            .unwrap()
            .try_run()
            .unwrap()
            .coll_time
            .mean_ns
    };
    let star = run(FabricKind::SwitchStar, 1);
    let mesh = run(FabricKind::Mesh, 1);
    assert!(
        mesh < star,
        "mesh intra phases are single-hop and must finish first: mesh {mesh:.0} vs star {star:.0} ns"
    );
}

/// Collectives are deterministic even against Poisson background traffic.
#[test]
fn collective_runs_are_deterministic() {
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 2,
    };
    let bg = Pattern::Custom { frac_inter: 1.0 };
    let a = run_collective(32, 256.0, spec, bg, 0.2);
    let b = run_collective(32, 256.0, spec, bg, 0.2);
    assert_eq!(a.coll_time.mean_ns, b.coll_time.mean_ns);
    assert_eq!(a.events, b.events);
    assert_eq!(a.delivered_msgs, b.delivered_msgs);
}

/// The full config→JSON→file→Sim pipeline carries the workload (what
/// `sauron run collective.json` executes).
#[test]
fn collective_config_runs_from_json_file() {
    let mut cfg = presets::collective_scaleout(
        32,
        256.0,
        CollectiveSpec {
            op: CollOp::AllToAll,
            scope: CollScope::PerNode,
            size_b: 128 * 1024,
            iters: 2,
        },
        Pattern::C5,
        0.0,
    );
    cfg.seed = 99;
    let dir = std::env::temp_dir().join("sauron_coll_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("collective.json");
    std::fs::write(&path, cfg.to_json_string()).unwrap();
    let loaded = sauron::SimConfig::load(&path).unwrap();
    assert!(matches!(loaded.workload, Workload::Collective(s) if s.op == CollOp::AllToAll));
    let r = Sim::new(loaded, &NativeProvider, BenchMode::None).unwrap().run();
    assert_eq!(r.coll_iters, 2);
    assert_eq!(r.coll_op, "all_to_all");
    assert!(r.coll_time.mean_ns > 0.0);
    std::fs::remove_dir_all(&dir).ok();
}
