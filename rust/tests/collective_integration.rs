//! Closed-loop collective workloads end-to-end through the world engine:
//! the sim-vs-analytic oracle on an uncongested intra-node ring, and the
//! paper's qualitative interference trend — with concurrent inter-node
//! background traffic, raising intra-node bandwidth does not improve
//! (and eventually degrades) hierarchical-AllReduce completion time,
//! because offered background load scales with the intra links while the
//! NIC boundary stays fixed.

use sauron::analytic::CollParams;
use sauron::config::{presets, CollOp, CollScope, CollectiveSpec, Pattern, Workload};
use sauron::net::world::{BenchMode, NativeProvider, Sim};

const MIB: u64 = 1 << 20;

fn run_collective(
    nodes: usize,
    gbs: f64,
    spec: CollectiveSpec,
    bg_pattern: Pattern,
    bg_load: f64,
) -> sauron::SimReport {
    let cfg = presets::collective_scaleout(nodes, gbs, spec, bg_pattern, bg_load);
    Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run()
}

/// Satellite oracle: simulated ring AllReduce on an uncongested
/// single-node group agrees with `CollParams::ring_allreduce` (α-β over
/// the PCIe chunk cost) within 5%.
#[test]
fn ring_allreduce_matches_analytic_oracle_within_5pct() {
    let spec = CollectiveSpec {
        op: CollOp::RingAllReduce,
        scope: CollScope::PerNode,
        size_b: MIB,
        iters: 3,
    };
    for gbs in [128.0, 256.0, 512.0] {
        let cfg = presets::collective_scaleout(32, gbs, spec, Pattern::C5, 0.0);
        let accels = cfg.node.accels_per_node as u32;
        let chunk = spec.size_b / accels as u64;
        let oracle = CollParams::from_pcie(&cfg.node.accel_link, accels, chunk)
            .ring_allreduce_ns(spec.size_b as f64);
        let r = Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run();
        assert_eq!(r.coll_iters, 3);
        let rel = (r.coll_time.mean_ns - oracle).abs() / oracle;
        assert!(
            rel < 0.05,
            "{gbs} GB/s: sim {:.1} ns vs oracle {oracle:.1} ns ({:.1}%)",
            r.coll_time.mean_ns,
            rel * 100.0
        );
        // The report's built-in prediction is the same oracle.
        let rel_report = (r.coll_pred_ns - oracle).abs() / oracle;
        assert!(rel_report < 1e-9, "report pred {} vs {oracle}", r.coll_pred_ns);
    }
}

/// Uncongested hierarchical AllReduce benefits from intra bandwidth: the
/// intra reduce/broadcast phases dominate and speed up 128→512 GB/s.
#[test]
fn hierarchical_uncongested_improves_with_intra_bandwidth() {
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: MIB,
        iters: 2,
    };
    let t128 = run_collective(32, 128.0, spec, Pattern::C5, 0.0).coll_time.mean_ns;
    let t512 = run_collective(32, 512.0, spec, Pattern::C5, 0.0).coll_time.mean_ns;
    assert!(
        t512 < 0.7 * t128,
        "512 GB/s should beat 128 GB/s uncongested: {t512:.0} vs {t128:.0} ns"
    );
    // The composed analytic prediction tracks the same order of magnitude
    // (sanity for the NIC-boundary pipeline model; the strict 5% oracle
    // is the per-node ring above).
    let r = run_collective(32, 128.0, spec, Pattern::C5, 0.0);
    assert!(r.coll_pred_ns > 0.0);
    let ratio = r.coll_time.mean_ns / r.coll_pred_ns;
    assert!((0.3..3.0).contains(&ratio), "sim/pred ratio {ratio:.2}");
}

/// Acceptance trend: against concurrent inter-node background traffic,
/// raising intra-node bandwidth does not improve hierarchical-AllReduce
/// completion — the background offered load grows with the intra links
/// (load is a fraction of link capacity), over-subscribing the fixed
/// 400 Gbps NIC and stalling the inter-exchange phase.
#[test]
fn hierarchical_congested_does_not_improve_with_intra_bandwidth() {
    // One iteration, and a measure window long enough that the background
    // generators stay live for the whole collective at every bandwidth.
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 1,
    };
    let bg = Pattern::Custom { frac_inter: 1.0 };
    let load = 0.35; // offered inter per node: 128 GB/s -> ~358 Gbps
                     // (below the 400 Gbps NIC); 256 -> ~717; 512 ->
                     // ~1434 — far past it, so the inter-exchange phase
                     // stalls behind background backlogs.
    let run = |gbs: f64, pattern: Pattern, load: f64| {
        let mut cfg = presets::collective_scaleout(32, gbs, spec, pattern, load);
        cfg.measure_us = 500.0;
        Sim::new(cfg, &NativeProvider, BenchMode::None).unwrap().run().coll_time.mean_ns
    };
    let t128 = run(128.0, bg, load);
    let t256 = run(256.0, bg, load);
    let t512 = run(512.0, bg, load);
    assert!(
        t512 >= 0.95 * t128,
        "raising intra bandwidth must not improve congested completion: \
         128 -> {t128:.0} ns, 256 -> {t256:.0} ns, 512 -> {t512:.0} ns"
    );
    assert!(
        t512.max(t256) >= t128,
        "trend: saturation at higher intra bandwidth should dominate: \
         128 -> {t128:.0} ns, 256 -> {t256:.0} ns, 512 -> {t512:.0} ns"
    );
    // And congestion must actually hurt at 512 vs its own uncongested run.
    let t512_clean = run(512.0, Pattern::C5, 0.0);
    assert!(
        t512 > 1.2 * t512_clean,
        "background traffic should degrade 512 GB/s completion: \
         {t512:.0} vs clean {t512_clean:.0} ns"
    );
}

/// Collectives are deterministic even against Poisson background traffic.
#[test]
fn collective_runs_are_deterministic() {
    let spec = CollectiveSpec {
        op: CollOp::HierarchicalAllReduce,
        scope: CollScope::Global,
        size_b: 256 * 1024,
        iters: 2,
    };
    let bg = Pattern::Custom { frac_inter: 1.0 };
    let a = run_collective(32, 256.0, spec, bg, 0.2);
    let b = run_collective(32, 256.0, spec, bg, 0.2);
    assert_eq!(a.coll_time.mean_ns, b.coll_time.mean_ns);
    assert_eq!(a.events, b.events);
    assert_eq!(a.delivered_msgs, b.delivered_msgs);
}

/// The full config→JSON→file→Sim pipeline carries the workload (what
/// `sauron run collective.json` executes).
#[test]
fn collective_config_runs_from_json_file() {
    let mut cfg = presets::collective_scaleout(
        32,
        256.0,
        CollectiveSpec {
            op: CollOp::AllToAll,
            scope: CollScope::PerNode,
            size_b: 128 * 1024,
            iters: 2,
        },
        Pattern::C5,
        0.0,
    );
    cfg.seed = 99;
    let dir = std::env::temp_dir().join("sauron_coll_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("collective.json");
    std::fs::write(&path, cfg.to_json_string()).unwrap();
    let loaded = sauron::SimConfig::load(&path).unwrap();
    assert!(matches!(loaded.workload, Workload::Collective(s) if s.op == CollOp::AllToAll));
    let r = Sim::new(loaded, &NativeProvider, BenchMode::None).unwrap().run();
    assert_eq!(r.coll_iters, 2);
    assert_eq!(r.coll_op, "all_to_all");
    assert!(r.coll_time.mean_ns > 0.0);
    std::fs::remove_dir_all(&dir).ok();
}
