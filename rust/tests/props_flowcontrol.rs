//! Property tests: flow-control / buffer invariants and message
//! conservation under randomized configurations (DESIGN.md inventory).

use sauron::config::{presets, Arrival, Pattern};
use sauron::net::world::{BenchMode, NativeProvider, Sim};
use sauron::testkit::{forall, Choice, FloatRange, Triple};
use sauron::units::Time;

fn build(nodes: usize, gbs: f64, pattern: Pattern, load: f64, arrival: Arrival) -> Sim {
    let mut cfg = presets::scaleout(nodes, gbs, pattern, load);
    cfg.warmup_us = 5.0;
    cfg.measure_us = 10.0;
    cfg.traffic.arrival = arrival;
    Sim::new(cfg, &NativeProvider, BenchMode::None).expect("valid config")
}

#[test]
fn prop_buffers_never_exceed_capacity() {
    let gen = Triple(
        Choice(&[128.0f64, 256.0, 512.0]),
        Choice(&[Pattern::C1, Pattern::C2, Pattern::C3, Pattern::C4, Pattern::C5]),
        FloatRange { lo: 0.05, hi: 1.0 },
    );
    forall(0xF10, 12, &gen, |&(gbs, pattern, load)| {
        let mut sim = build(32, gbs, pattern, load, Arrival::Poisson);
        // Check invariants at several points mid-run, not just at the end.
        for step in 1..=4 {
            let t = Time::from_us(step as f64 * 3.0);
            sim.engine_mut().run_until(t);
            sim.world().check_invariants().map_err(|e| format!("{gbs}/{pattern:?}/{load}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_messages_conserved_after_drain() {
    // Stop generating, drain the network completely: every injected
    // message either completed or is still queued nowhere (all units
    // delivered) -- nothing lost, nothing duplicated.
    let gen = Triple(
        Choice(&[128.0f64, 512.0]),
        Choice(&[Pattern::C1, Pattern::C4, Pattern::C5]),
        FloatRange { lo: 0.1, hi: 0.9 },
    );
    forall(0xD8A1, 8, &gen, |&(gbs, pattern, load)| {
        let mut sim = build(32, gbs, pattern, load, Arrival::Poisson);
        let end = sim.world().end_time();
        sim.engine_mut().run_until(end);
        // Let the network drain (generators stop at `end`).
        sim.engine_mut().run();
        let w = sim.world();
        if w.units_in_flight() != 0 {
            return Err(format!("{} units stuck in flight", w.units_in_flight()));
        }
        if w.msgs_in_flight() != 0 {
            return Err(format!("{} messages never completed", w.msgs_in_flight()));
        }
        if w.injected_msgs != w.completed_msgs {
            return Err(format!(
                "injected {} != completed {}",
                w.injected_msgs, w.completed_msgs
            ));
        }
        w.check_invariants()?;
        Ok(())
    });
}

#[test]
fn prop_deterministic_replay() {
    let gen = Triple(
        Choice(&[128.0f64, 256.0]),
        Choice(&[Pattern::C2, Pattern::C5]),
        FloatRange { lo: 0.1, hi: 1.0 },
    );
    forall(0x5EED, 6, &gen, |&(gbs, pattern, load)| {
        let a = build(32, gbs, pattern, load, Arrival::Poisson).run();
        let b = build(32, gbs, pattern, load, Arrival::Poisson).run();
        if a.events != b.events || a.delivered_msgs != b.delivered_msgs {
            return Err(format!(
                "non-deterministic: {}/{} vs {}/{}",
                a.events, a.delivered_msgs, b.events, b.delivered_msgs
            ));
        }
        if a.intra_tput_gbs != b.intra_tput_gbs || a.fct.mean_ns != b.fct.mean_ns {
            return Err("metrics differ between identical runs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_throughput_bounded_by_offered_load() {
    let gen = Triple(
        Choice(&[128.0f64, 256.0, 512.0]),
        Choice(&[Pattern::C1, Pattern::C3, Pattern::C5]),
        FloatRange { lo: 0.05, hi: 0.6 },
    );
    forall(0xB0DE, 10, &gen, |&(gbs, pattern, load)| {
        let r = build(32, gbs, pattern, load, Arrival::Deterministic).run();
        let total = r.intra_tput_gbs + r.inter_tput_gbs;
        // Strict throughput can never exceed offered (with margin for
        // window edge effects).
        if total > r.offered_gbs * 1.10 {
            return Err(format!("strict {total:.1} > offered {:.1}", r.offered_gbs));
        }
        Ok(())
    });
}

#[test]
fn prop_inter_share_tracks_pattern() {
    // At light load the delivered inter fraction approximates the
    // pattern's configured split.
    let gen = Choice(&[Pattern::C1, Pattern::C2, Pattern::C3, Pattern::C4]);
    forall(0xF8AC, 4, &gen, |&pattern| {
        let r = build(32, 128.0, pattern, 0.2, Arrival::Poisson).run();
        let total = r.intra_tput_gbs + r.inter_tput_gbs;
        let frac = r.inter_tput_gbs / total;
        let want = pattern.frac_inter();
        if (frac - want).abs() > 0.05 {
            return Err(format!("{pattern:?}: inter frac {frac:.3} vs configured {want}"));
        }
        Ok(())
    });
}
