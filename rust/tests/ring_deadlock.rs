//! Ring-fabric credit-cycle deadlock regression.
//!
//! The Ring fabric's hops form a physical cycle with no virtual
//! channels, so sustained all-intra overload with shallow switch queues
//! parks a wait-for cycle of links that can never free queue space.
//! The world diagnoses the cycle (`World::is_deadlocked`) and
//! `Sim::try_run` must surface it as the *structured*
//! `SimError::CreditCycleDeadlock` — the sweep coordinator quarantines
//! on the downcast, not on string-matching the message.

use sauron::config::{presets, FabricConfig, FabricKind, Pattern};
use sauron::net::world::{BenchMode, NativeProvider, Sim, SimError};

/// All-intra ring overload with switch queues two messages deep.
fn ring_cfg(load: f64) -> sauron::config::SimConfig {
    let mut cfg = presets::with_fabric(
        presets::scaleout(4, 256.0, Pattern::Custom { frac_inter: 0.0 }, load),
        FabricConfig::new(FabricKind::Ring, 2),
    );
    // Shallow enough that the 8-accel ring parks a full cycle quickly;
    // still >= msg_size_b so validate() accepts the whole-message unit.
    cfg.node.switch_queue_b = 2 * cfg.traffic.msg_size_b;
    cfg.warmup_us = 5.0;
    cfg.measure_us = 30.0;
    cfg
}

#[test]
fn ring_overload_deadlock_is_structured() {
    // Escalating offered loads: the exact tipping point depends on the
    // arrival draw, but sustained near-saturation must trip the cycle
    // at least once, and *every* failure must carry the typed error.
    let mut deadlocks = 0usize;
    for load in [0.7, 0.85, 0.95, 0.98] {
        let cfg = ring_cfg(load);
        cfg.validate().unwrap_or_else(|e| panic!("load {load}: config invalid: {e}"));
        let sim = Sim::new(cfg, &NativeProvider, BenchMode::None)
            .unwrap_or_else(|e| panic!("load {load}: {e:#}"));
        match sim.try_run() {
            Ok(r) => {
                // Legitimate below the tipping point — but the run must
                // have actually moved traffic, not silently idled.
                assert!(r.delivered_msgs > 0, "load {load}: no traffic moved");
            }
            Err(e) => {
                let se = e.downcast_ref::<SimError>().unwrap_or_else(|| {
                    panic!("load {load}: ring failure is not a SimError: {e:#}")
                });
                match se {
                    SimError::CreditCycleDeadlock { parked_units, inflight_msgs, .. } => {
                        assert!(*parked_units > 0, "load {load}: deadlock with nothing parked");
                        assert!(*inflight_msgs > 0, "load {load}: deadlock with nothing in flight");
                    }
                    other => panic!("load {load}: wrong SimError variant: {other}"),
                }
                // The rendered message must keep naming the fix knobs.
                let msg = se.to_string();
                assert!(msg.contains("credit-cycle deadlock"), "{msg}");
                assert!(msg.contains("switch_queue_b"), "{msg}");
                deadlocks += 1;
            }
        }
    }
    // If the ring ever gains virtual channels (making the cycle
    // unreachable), this assert is the flag to rewrite the test, not a
    // bug in the fabric.
    assert!(
        deadlocks > 0,
        "no load level deadlocked the shallow-queue ring; if virtual channels were \
         added, update this regression test"
    );
}

#[test]
fn ring_below_saturation_still_completes() {
    // The same topology well below saturation must finish cleanly —
    // the deadlock is a load regime, not a structural property.
    let cfg = ring_cfg(0.2);
    let r = Sim::new(cfg, &NativeProvider, BenchMode::None)
        .expect("build")
        .try_run()
        .expect("low-load ring run completes");
    assert!(r.delivered_msgs > 0);
}
