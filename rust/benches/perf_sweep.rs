//! Sweep-scale throughput bench (EXPERIMENTS.md §Perf, iteration 3):
//! points/sec over a small fabric × bandwidth × load grid, comparing the
//! **fresh-build** arm (full `World` construction per point — the
//! pre-blueprint coordinator behavior) against the **blueprint-reuse**
//! arm (one compiled `WorldBlueprint` per fabric × bandwidth axis value,
//! one pinned `Sim` per blueprint, zero-reallocation `reset` between
//! points — what `coordinator::run_sweep` does per worker).
//!
//! Windows are deliberately short so construction cost is a large share
//! of each point, mirroring the many-configuration regime of the paper's
//! parameter sweeps where rebuild overhead dominates.
//!
//! Run: `cargo bench --bench perf_sweep`. Prints the grep-friendly
//! table plus the reuse-over-fresh speedup, and writes
//! `BENCH_sweep.json` next to `BENCH_hotpath.json` for CI's perf-smoke
//! comparison (python/bench_compare.py).

use std::sync::Arc;

use sauron::benchkit::Bench;
use sauron::config::{presets, FabricConfig, FabricKind, Pattern, SimConfig};
use sauron::net::world::{BenchMode, NativeProvider, Sim, WorldBlueprint};

/// Reference grid: 2 fabrics × 2 bandwidths × 3 loads = 12 points,
/// grouped into 4 blueprints (fabric × bandwidth are compile-phase,
/// load/pattern/seed run-phase).
fn grid() -> Vec<SimConfig> {
    let mut out = Vec::new();
    for kind in [FabricKind::SwitchStar, FabricKind::Mesh] {
        for gbs in [128.0, 512.0] {
            for load in [0.2, 0.5, 0.8] {
                let mut cfg = presets::with_fabric(
                    presets::scaleout(32, gbs, Pattern::C2, load),
                    FabricConfig::new(kind, 2),
                );
                cfg.warmup_us = 2.0;
                cfg.measure_us = 3.0;
                cfg.seed = 0x5EE7 ^ (out.len() as u64).wrapping_mul(0x9E3779B97F4A7C15);
                out.push(cfg);
            }
        }
    }
    out
}

fn run_fresh(configs: &[SimConfig]) -> u64 {
    let mut events = 0u64;
    for cfg in configs {
        let r = Sim::new(cfg.clone(), &NativeProvider, BenchMode::None)
            .expect("valid grid point")
            .try_run()
            .expect("grid point runs");
        events += r.events;
    }
    events
}

fn run_reused(configs: &[SimConfig], sims: &mut Vec<(String, Sim)>) -> u64 {
    let mut events = 0u64;
    for cfg in configs {
        let key = WorldBlueprint::key_for(cfg, BenchMode::None, &[]);
        if let Some((_, sim)) = sims.iter_mut().find(|(k, _)| *k == key) {
            sim.reset(cfg.clone()).expect("run-phase delta");
            events += sim.try_run_mut().expect("grid point runs").events;
        } else {
            let bp = Arc::new(
                WorldBlueprint::compile(cfg.clone(), &NativeProvider, BenchMode::None, &[])
                    .expect("valid grid point"),
            );
            let mut sim = Sim::from_blueprint(&bp, cfg.clone()).expect("valid grid point");
            events += sim.try_run_mut().expect("grid point runs").events;
            sims.push((key, sim));
        }
    }
    events
}

fn main() {
    let configs = grid();
    let points = configs.len() as f64;

    // Equivalence sanity before timing anything: both arms must produce
    // the same simulated work (props_reuse.rs holds the full property).
    {
        let mut sims = Vec::new();
        let fresh = run_fresh(&configs);
        let reused = run_reused(&configs, &mut sims);
        assert_eq!(fresh, reused, "arms disagree on simulated events — reuse is broken");
    }

    let mut b = Bench::new();

    let fresh_cfgs = configs.clone();
    b.bench_units("perf/sweep_fresh_build", points, "points", move || run_fresh(&fresh_cfgs));

    // Blueprints + pinned Sims persist across bench iterations, exactly
    // like a sweep worker's state persists across points.
    let reuse_cfgs = configs.clone();
    let mut sims: Vec<(String, Sim)> = Vec::new();
    b.bench_units("perf/sweep_blueprint_reuse", points, "points", move || {
        run_reused(&reuse_cfgs, &mut sims)
    });

    // Sharded arm: the identical grid with per-node event shards — a
    // run-phase knob, so the same blueprints and pinned Sims carry over
    // and every report stays bit-identical (tests/props_shards.rs). The
    // rate delta against the reuse arm is the sharding win at sweep
    // scale.
    let shards =
        std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(8).min(64);
    let mut shard_cfgs = configs.clone();
    for c in &mut shard_cfgs {
        c.shards = shards;
    }
    let mut shard_sims: Vec<(String, Sim)> = Vec::new();
    b.bench_units("perf/sweep_blueprint_reuse_sharded", points, "points", move || {
        run_reused(&shard_cfgs, &mut shard_sims)
    });

    let fresh_rate = b.results[0].per_second().unwrap_or(0.0);
    let reuse_rate = b.results[1].per_second().unwrap_or(0.0);
    if fresh_rate > 0.0 {
        println!(
            "sweep points/sec: fresh {:.1}, blueprint-reuse {:.1} ({:.2}x)",
            fresh_rate,
            reuse_rate,
            reuse_rate / fresh_rate
        );
    }

    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
    match b.write_json(std::path::Path::new("BENCH_sweep.json")) {
        Ok(()) => println!("wrote BENCH_sweep.json ({} benches)", b.results.len()),
        Err(e) => eprintln!("could not write BENCH_sweep.json: {e}"),
    }
}
