//! Shared helpers for the paper-artifact benches.
//!
//! Every bench regenerates one paper table/figure through the public API
//! and times the regeneration with `sauron::benchkit`. Env knobs:
//! `SAURON_BENCH_FULL=1` uses the paper's full load axis (slow on one
//! core); `SAURON_BENCH_MS` overrides the per-bench measurement budget.

#![allow(dead_code)]
use std::sync::Arc;

use sauron::config::Pattern;
use sauron::coordinator::{self, SweepSpec};
use sauron::net::world::{NativeProvider, SerProvider, SimReport};
use sauron::runtime::Runtime;

pub fn full() -> bool {
    std::env::var("SAURON_BENCH_FULL").is_ok()
}

/// Provider for benches: HLO runtime when artifacts exist, else native.
pub fn provider() -> Box<dyn SerProvider> {
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => {
            eprintln!("# provider: hlo/pjrt");
            Box::new(rt)
        }
        Err(_) => {
            eprintln!("# provider: native (run `make artifacts` for the HLO path)");
            Box::new(NativeProvider)
        }
    }
}

/// Figure sweep spec: trimmed by default, paper grid with
/// SAURON_BENCH_FULL.
pub fn fig_spec(nodes: usize) -> SweepSpec {
    let mut spec = SweepSpec::paper(nodes);
    if !full() {
        spec.loads = vec![0.2, 0.5, 0.8, 1.0];
        if nodes > 32 {
            // 128-node points are ~4x the work; trim the grid further.
            spec.patterns = vec![Pattern::C1, Pattern::C3, Pattern::C5];
            spec.intra_gbs = vec![128.0, 512.0];
        }
    }
    spec
}

/// Run a figure sweep once (used inside the timed closure).
pub fn run_fig(spec: &SweepSpec, provider: &dyn SerProvider) -> Vec<SimReport> {
    let snapshot = Arc::new(coordinator::snapshot_provider(spec, provider));
    coordinator::run_sweep(spec, snapshot, None).expect("sweep")
}

/// Count simulated events across reports (throughput unit for benchkit).
pub fn total_events(reports: &[SimReport]) -> f64 {
    reports.iter().map(|r| r.events as f64).sum()
}
