//! Paper Figure 7: intra-node metrics vs load on the 128-node RLFT
//! (1024 accelerators). Trends must match Fig 5 with ~4x throughput.
//!
//! Run: `cargo bench --bench fig7_intra_128`

mod common;

use sauron::benchkit::Bench;
use sauron::coordinator::results;
use sauron::report::figures::{render_figure, FigureKind};

fn main() {
    let provider = common::provider();
    let spec = common::fig_spec(128);
    eprintln!("# fig7: {} sweep points (128 nodes)", spec.points());

    let reports = common::run_fig(&spec, provider.as_ref());
    println!("{}", render_figure(&reports, FigureKind::IntraThroughput));
    println!("{}", render_figure(&reports, FigureKind::IntraLatency));
    results::write_csv(std::path::Path::new("results/fig7_intra_128.csv"), &reports).unwrap();

    let events = common::total_events(&reports);
    let mut b = Bench::new();
    b.bench_units("fig7/sweep_128n", events, "events", || {
        common::run_fig(&spec, provider.as_ref())
    });
    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
