//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the DES core and the
//! end-to-end simulation step, isolated from figure regeneration.
//!
//! Run: `cargo bench --bench perf_hotpath`

mod common;

use sauron::benchkit::Bench;
use sauron::config::{presets, Pattern};
use sauron::net::world::{BenchMode, NativeProvider, Sim};
use sauron::sim::{Engine, EventQueue, Model};
use sauron::units::Time;

/// Pure event-loop cost: self-rescheduling no-op events.
struct Spin {
    left: u64,
}
impl Model for Spin {
    type Event = ();
    fn handle(&mut self, now: Time, _ev: (), q: &mut EventQueue<()>) {
        if self.left > 0 {
            self.left -= 1;
            q.push(now + Time::from_ps(100), ());
        }
    }
}

fn main() {
    let mut b = Bench::new();

    // 1. Raw DES engine dispatch rate (single chain).
    const N: u64 = 1_000_000;
    b.bench_units("perf/engine_dispatch_chain", N as f64, "events", || {
        let mut e = Engine::new(Spin { left: N });
        e.schedule(Time::ZERO, ());
        e.run()
    });

    // 2. Raw DES with a deep heap (64k concurrent chains).
    const CHAINS: u64 = 65_536;
    const PER: u64 = 4;
    b.bench_units("perf/engine_dispatch_wide", (CHAINS * (PER + 1)) as f64, "events", || {
        let mut e = Engine::new(Spin { left: CHAINS * PER });
        for i in 0..CHAINS {
            e.schedule(Time::from_ps(i), ());
        }
        e.run()
    });

    // 3. End-to-end world step at moderate load (the real hot path).
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, 0.6);
    cfg.warmup_us = 10.0;
    cfg.measure_us = 10.0;
    let probe = Sim::new(cfg.clone(), &NativeProvider, BenchMode::None).unwrap().run();
    b.bench_units("perf/world_32n_c1_60pct", probe.events as f64, "events", || {
        Sim::new(cfg.clone(), &NativeProvider, BenchMode::None).unwrap().run()
    });

    // 4. Saturated world (backpressure-heavy path).
    let mut cfg2 = presets::scaleout(32, 512.0, Pattern::C1, 1.0);
    cfg2.warmup_us = 10.0;
    cfg2.measure_us = 10.0;
    let probe2 = Sim::new(cfg2.clone(), &NativeProvider, BenchMode::None).unwrap().run();
    b.bench_units("perf/world_32n_c1_saturated", probe2.events as f64, "events", || {
        Sim::new(cfg2.clone(), &NativeProvider, BenchMode::None).unwrap().run()
    });

    // 5. World construction cost (128 nodes — allocation path).
    let cfg3 = presets::scaleout(128, 128.0, Pattern::C3, 0.0);
    b.bench("perf/world_build_128n", || {
        Sim::new(cfg3.clone(), &NativeProvider, BenchMode::None).unwrap()
    });

    // 6. PJRT artifact table build, when artifacts exist.
    if let Ok(rt) = sauron::runtime::Runtime::load(&sauron::runtime::Runtime::default_dir()) {
        let p = sauron::analytic::PcieParams::generic_accel_link(512.0);
        let sizes: Vec<u32> = (1..=1024).map(|i| i * 977).collect();
        b.bench_units("perf/pjrt_pcie_table_1024", 1024.0, "lat", || {
            rt.pcie_latency_ns_exec(&p, &sizes).unwrap()
        });
    }

    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
