//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the DES core and the
//! end-to-end simulation step, isolated from figure regeneration.
//!
//! Every world benchmark reports throughput in **scalar-equivalent
//! events/sec**: the unit of work is the event count of the force-scalar
//! (coalescing-disabled) engine on the same config, so rates stay
//! comparable across engine generations no matter how many heap events
//! the coalesced engine actually dispatches.
//!
//! Run: `cargo bench --bench perf_hotpath`. Prints the grep-friendly
//! table, appends results/bench_history.csv, and writes
//! `BENCH_hotpath.json` — the recorded perf trajectory that CI's
//! perf-smoke job diffs against the committed baseline
//! (python/bench_compare.py).

mod common;

use sauron::benchkit::Bench;
use sauron::config::{presets, CollOp, CollScope, CollectiveSpec, Pattern, SimConfig};
use sauron::net::world::{BenchMode, NativeProvider, Sim};
use sauron::sim::{Engine, EventQueue, Model};
use sauron::units::Time;

/// Pure event-loop cost: self-rescheduling no-op events.
struct Spin {
    left: u64,
}
impl Model for Spin {
    type Event = ();
    fn handle(&mut self, now: Time, _ev: (), q: &mut EventQueue<()>) {
        if self.left > 0 {
            self.left -= 1;
            q.push(now + Time::from_ps(100), ());
        }
    }
}

/// Scalar-equivalent event count of `cfg` — the logical unit of work a
/// world benchmark divides wall time by. All bench arms run with
/// telemetry off (the preset default), so this count is the
/// deterministic engine-behavior fingerprint `python/bench_compare.py
/// --require-equal-units` diffs against the committed baseline.
fn scalar_events(cfg: &SimConfig) -> f64 {
    let mut scalar = cfg.clone();
    scalar.coalescing = false;
    assert!(!scalar.telemetry.enabled, "bench arms are telemetry-off by contract");
    Sim::new(scalar, &NativeProvider, BenchMode::None).unwrap().run().events as f64
}

fn main() {
    let mut b = Bench::new();

    // 1. Raw DES engine dispatch rate (single chain; the front-slot fast
    //    path of sim::queue never touches the heap here).
    const N: u64 = 1_000_000;
    b.bench_units("perf/engine_dispatch_chain", N as f64, "events", || {
        let mut e = Engine::new(Spin { left: N });
        e.schedule(Time::ZERO, ());
        e.run()
    });

    // 2. Raw DES with a deep heap (64k concurrent chains).
    const CHAINS: u64 = 65_536;
    const PER: u64 = 4;
    b.bench_units("perf/engine_dispatch_wide", (CHAINS * (PER + 1)) as f64, "events", || {
        let mut e = Engine::new(Spin { left: CHAINS * PER });
        for i in 0..CHAINS {
            e.schedule(Time::from_ps(i), ());
        }
        e.run()
    });

    // 3. End-to-end world step at moderate load (the real hot path).
    let mut cfg = presets::scaleout(32, 256.0, Pattern::C1, 0.6);
    cfg.warmup_us = 10.0;
    cfg.measure_us = 10.0;
    let units = scalar_events(&cfg);
    b.bench_units("perf/world_32n_c1_60pct", units, "events", || {
        Sim::new(cfg.clone(), &NativeProvider, BenchMode::None).unwrap().run()
    });

    // 4. Saturated world (backpressure-heavy path: deep queues, long
    //    delivery trains, waiter truncation).
    let mut cfg2 = presets::scaleout(32, 512.0, Pattern::C1, 1.0);
    cfg2.warmup_us = 10.0;
    cfg2.measure_us = 10.0;
    let units2 = scalar_events(&cfg2);
    b.bench_units("perf/world_32n_c1_saturated", units2, "events", || {
        Sim::new(cfg2.clone(), &NativeProvider, BenchMode::None).unwrap().run()
    });

    // 4b. Large-world saturated arm (4096 nodes): the multi-thousand-node
    //     regime the hop-generic trains + event shards target. Windows are
    //     short so the scalar reference stays affordable; the sharded
    //     variant runs the identical config with per-node event shards
    //     (bit-identical report — tests/props_shards.rs) and divides by
    //     the same scalar-equivalent unit count, so the two rates read
    //     directly as the sharding speedup.
    let mut cfg4k = presets::scaleout(4096, 256.0, Pattern::C1, 1.0);
    cfg4k.warmup_us = 1.0;
    cfg4k.measure_us = 2.0;
    let units4k = scalar_events(&cfg4k);
    b.bench_units("perf/world_4096n_c1_saturated", units4k, "events", || {
        Sim::new(cfg4k.clone(), &NativeProvider, BenchMode::None).unwrap().run()
    });
    let mut cfg4ks = cfg4k.clone();
    cfg4ks.shards =
        std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(8).min(64);
    b.bench_units("perf/world_4096n_c1_saturated_sharded", units4k, "events", || {
        Sim::new(cfg4ks.clone(), &NativeProvider, BenchMode::None).unwrap().run()
    });

    // 5. Collective world: hierarchical AllReduce with inter-node
    //    background traffic (multi-transaction inter sends are where
    //    trains pay off for closed-loop workloads).
    let mut cfg3 = presets::collective_scaleout(
        8,
        256.0,
        CollectiveSpec {
            op: CollOp::HierarchicalAllReduce,
            scope: CollScope::Global,
            size_b: 1 << 20,
            iters: 2,
        },
        Pattern::Custom { frac_inter: 1.0 },
        0.2,
    );
    cfg3.warmup_us = 5.0;
    cfg3.measure_us = 20.0;
    let units3 = scalar_events(&cfg3);
    b.bench_units("perf/world_collective_hier_1mib", units3, "events", || {
        Sim::new(cfg3.clone(), &NativeProvider, BenchMode::None).unwrap().run()
    });

    // 6. World construction cost (128 nodes — allocation path).
    let cfg4 = presets::scaleout(128, 128.0, Pattern::C3, 0.0);
    b.bench("perf/world_build_128n", || {
        Sim::new(cfg4.clone(), &NativeProvider, BenchMode::None).unwrap()
    });

    // 7. PJRT artifact table build, when artifacts exist.
    if let Ok(rt) = sauron::runtime::Runtime::load(&sauron::runtime::Runtime::default_dir()) {
        let p = sauron::analytic::PcieParams::generic_accel_link(512.0);
        let sizes: Vec<u32> = (1..=1024).map(|i| i * 977).collect();
        b.bench_units("perf/pjrt_pcie_table_1024", 1024.0, "lat", || {
            rt.pcie_latency_ns_exec(&p, &sizes).unwrap()
        });
    }

    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
    match b.write_json(std::path::Path::new("BENCH_hotpath.json")) {
        Ok(()) => println!("wrote BENCH_hotpath.json ({} benches)", b.results.len()),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}
