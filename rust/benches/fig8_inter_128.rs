//! Paper Figure 8: inter-node metrics vs load on the 128-node RLFT.
//!
//! Run: `cargo bench --bench fig8_inter_128`

mod common;

use sauron::benchkit::Bench;
use sauron::coordinator::results;
use sauron::report::figures::{render_figure, FigureKind};

fn main() {
    let provider = common::provider();
    let spec = common::fig_spec(128);
    eprintln!("# fig8: {} sweep points (128 nodes)", spec.points());

    let reports = common::run_fig(&spec, provider.as_ref());
    println!("{}", render_figure(&reports, FigureKind::InterThroughput));
    println!("{}", render_figure(&reports, FigureKind::Fct));
    results::write_csv(std::path::Path::new("results/fig8_inter_128.csv"), &reports).unwrap();

    let events = common::total_events(&reports);
    let mut b = Bench::new();
    b.bench_units("fig8/sweep_128n", events, "events", || {
        common::run_fig(&spec, provider.as_ref())
    });
    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
