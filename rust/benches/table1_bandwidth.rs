//! Paper Table 1: `ib_write` bandwidth vs message size on the CELLIA
//! model. Prints the regenerated rows and times the regeneration.
//!
//! Run: `cargo bench --bench table1_bandwidth` (SAURON_BENCH_FULL=1 for
//! all 16 sizes).

mod common;

use sauron::benchkit::Bench;
use sauron::report::tables;
use sauron::traffic::ib_bench::{self, TEST_SIZES};

fn main() {
    let provider = common::provider();
    let sizes: Vec<u64> = if common::full() {
        TEST_SIZES.to_vec()
    } else {
        vec![128, 4096, 65536, 1 << 20, 4 << 20]
    };

    // Regenerate the table once for display + correctness.
    let points: Vec<_> =
        sizes.iter().map(|&s| ib_bench::bandwidth_test(provider.as_ref(), s).unwrap()).collect();
    println!("{}", tables::render_table1(&points));
    let err = tables::geomean_abs_rel_err(
        &points.iter().map(|p| (p.sim_gib_s, p.paper_gib_s)).collect::<Vec<_>>(),
    );
    println!("geomean |rel err| = {:.1}%\n", err * 100.0);

    // Time each row's regeneration.
    let mut b = Bench::new();
    for &s in &sizes {
        b.bench(&format!("table1/bw_test/{s}B"), || {
            ib_bench::bandwidth_test(provider.as_ref(), s).unwrap()
        });
    }
    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
