//! Paper Figure 6: inter-node throughput + flow completion time vs load
//! on the 32-node RLFT (same sweep as Fig 5, inter-side metrics).
//!
//! Run: `cargo bench --bench fig6_inter_32`

mod common;

use sauron::benchkit::Bench;
use sauron::coordinator::results;
use sauron::report::figures::{render_figure, FigureKind};

fn main() {
    let provider = common::provider();
    let spec = common::fig_spec(32);
    eprintln!("# fig6: {} sweep points", spec.points());

    let reports = common::run_fig(&spec, provider.as_ref());
    println!("{}", render_figure(&reports, FigureKind::InterThroughput));
    println!("{}", render_figure(&reports, FigureKind::Fct));
    results::write_csv(std::path::Path::new("results/fig6_inter_32.csv"), &reports).unwrap();

    let events = common::total_events(&reports);
    let mut b = Bench::new();
    b.bench_units("fig6/sweep_32n", events, "events", || {
        common::run_fig(&spec, provider.as_ref())
    });
    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
