//! Paper Figure 4: simulated vs measured `ib_write` bandwidth + latency
//! curves (the validation figure). Regenerates both series, writes the
//! CSV, and times the full regeneration.
//!
//! Run: `cargo bench --bench fig4_validation`

mod common;

use sauron::benchkit::Bench;
use sauron::report::tables;
use sauron::traffic::ib_bench::{self, TEST_SIZES};

fn main() {
    let provider = common::provider();
    let sizes: Vec<u64> = if common::full() {
        TEST_SIZES.to_vec()
    } else {
        vec![128, 1024, 4096, 32768, 262144, 2 << 20]
    };

    let regen = || {
        let bw: Vec<_> = sizes
            .iter()
            .map(|&s| ib_bench::bandwidth_test(provider.as_ref(), s).unwrap())
            .collect();
        let lat: Vec<_> = sizes
            .iter()
            .map(|&s| ib_bench::latency_test(provider.as_ref(), s).unwrap())
            .collect();
        (bw, lat)
    };

    let (bw, lat) = regen();
    println!("Figure 4a (bandwidth, GiB/s) and 4b (latency, us): sim vs paper series");
    println!("{:>10} {:>10} {:>10} {:>12} {:>12}", "size", "bw_paper", "bw_sim", "lat_paper", "lat_sim");
    let mut csv = String::from("size_b,paper_bw_gib,sim_bw_gib,paper_lat_us,sim_lat_us\n");
    for (b, l) in bw.iter().zip(&lat) {
        println!(
            "{:>10} {:>10.2} {:>10.2} {:>12.2} {:>12.2}",
            b.size_b, b.paper_gib_s, b.sim_gib_s, l.paper_us, l.sim_us
        );
        csv.push_str(&format!(
            "{},{},{},{},{}\n",
            b.size_b, b.paper_gib_s, b.sim_gib_s, l.paper_us, l.sim_us
        ));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig4_validation.csv", csv).unwrap();
    let bw_err = tables::geomean_abs_rel_err(
        &bw.iter().map(|p| (p.sim_gib_s, p.paper_gib_s)).collect::<Vec<_>>(),
    );
    let lat_err = tables::geomean_abs_rel_err(
        &lat.iter().map(|p| (p.sim_us, p.paper_us)).collect::<Vec<_>>(),
    );
    println!("\ngeomean |rel err|: bw {:.1}%, lat {:.1}%\n", bw_err * 100.0, lat_err * 100.0);

    let mut b = Bench::new();
    b.bench("fig4/full_regeneration", regen);
    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
