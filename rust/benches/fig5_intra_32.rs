//! Paper Figure 5: intra-node throughput + latency vs traffic load on the
//! 32-node RLFT (256 accelerators), C1-C5 x {128,256,512} GB/s.
//!
//! Run: `cargo bench --bench fig5_intra_32` (SAURON_BENCH_FULL=1 for the
//! paper's 20-point load axis).

mod common;

use sauron::benchkit::Bench;
use sauron::coordinator::results;
use sauron::report::figures::{render_figure, FigureKind};

fn main() {
    let provider = common::provider();
    let spec = common::fig_spec(32);
    eprintln!("# fig5: {} sweep points", spec.points());

    let reports = common::run_fig(&spec, provider.as_ref());
    println!("{}", render_figure(&reports, FigureKind::IntraThroughput));
    println!("{}", render_figure(&reports, FigureKind::IntraLatency));
    results::write_csv(std::path::Path::new("results/fig5_intra_32.csv"), &reports).unwrap();

    let events = common::total_events(&reports);
    let mut b = Bench::new();
    b.bench_units("fig5/sweep_32n", events, "events", || {
        common::run_fig(&spec, provider.as_ref())
    });
    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
