//! Paper Table 2: `ib_write` one-way latency vs message size on the
//! CELLIA model.
//!
//! Run: `cargo bench --bench table2_latency`

mod common;

use sauron::benchkit::Bench;
use sauron::report::tables;
use sauron::traffic::ib_bench::{self, TEST_SIZES};

fn main() {
    let provider = common::provider();
    let sizes: Vec<u64> = if common::full() {
        TEST_SIZES.to_vec()
    } else {
        vec![128, 4096, 65536, 1 << 20, 4 << 20]
    };

    let points: Vec<_> =
        sizes.iter().map(|&s| ib_bench::latency_test(provider.as_ref(), s).unwrap()).collect();
    println!("{}", tables::render_table2(&points));
    let err = tables::geomean_abs_rel_err(
        &points.iter().map(|p| (p.sim_us, p.paper_us)).collect::<Vec<_>>(),
    );
    println!("geomean |rel err| = {:.1}%\n", err * 100.0);

    let mut b = Bench::new();
    for &s in &sizes {
        b.bench(&format!("table2/lat_test/{s}B"), || {
            ib_bench::latency_test(provider.as_ref(), s).unwrap()
        });
    }
    b.append_csv(std::path::Path::new("results/bench_history.csv")).ok();
}
