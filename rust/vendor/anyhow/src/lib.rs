//! Minimal in-tree mirror of the `anyhow` API surface this project uses.
//!
//! The build image has no crates.io access, so the workspace vendors this
//! shim as a path dependency instead of pulling the real crate. Supported
//! subset (kept intentionally tiny — extend only when a call site needs
//! it):
//!
//! * [`Error`] — a string-carrying error with a context chain.
//! * [`Result`] — `Result<T, Error>` alias with the usual default param.
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — the constructor macros.
//! * `Error::context` — context wrapping (pool.rs error reporting).
//! * `From<E: std::error::Error>` — so `?` converts std errors.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket `From` impl coherent.

use std::fmt;

/// A string-backed error with an outermost-first context chain.
pub struct Error {
    /// Context messages, outermost first, then the root message last.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a preformatted message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (mirrors `anyhow::Error::context`).
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (what plain `Display` shows).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, real-anyhow style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints errors via Debug; show
        // the full chain there too.
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result<T>` — plain `Result` with [`Error`] as the default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                ::std::concat!("condition failed: ", ::std::stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let x = 3;
        let e = anyhow!("value {x} and {}", 4);
        assert_eq!(format!("{e}"), "value 3 and 4");
        let from_display = anyhow!(String::from("owned message"));
        assert_eq!(format!("{from_display}"), "owned message");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(false).unwrap(), 7);
        let e = fails(true).unwrap_err();
        assert_eq!(format!("{e}"), "flag was true");
        fn b() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(format!("{}", b().unwrap_err()), "boom 1");
    }

    #[test]
    fn context_chains_render_in_alternate() {
        let e = anyhow!("root cause").context("outer job");
        assert_eq!(format!("{e}"), "outer job");
        assert_eq!(format!("{e:#}"), "outer job: root cause");
        assert_eq!(format!("{e:?}"), "outer job: root cause");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(format!("{}", parse("nope").unwrap_err()).contains("invalid digit"));
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/real/path/xyz")?)
        }
        assert!(io().is_err());
    }
}
