//! Offline API stub for the external `xla` PJRT bindings crate.
//!
//! The real crate wraps the XLA C++ runtime, which the offline build
//! image cannot ship. This stub mirrors exactly the API surface
//! `sauron`'s `pjrt` feature consumes so the gated runtime path keeps
//! compiling (and stays under `clippy -D warnings`) in CI. Every entry
//! point that would need a live PJRT client returns [`Error`], which
//! routes `runtime::Runtime::load` onto its documented native-mirror
//! fallback — the same behavior as building without the feature.
//!
//! Deployments with the real bindings replace this path dependency (a
//! `[patch]` or a changed path in Cargo.toml); no `sauron` code changes.

/// Error type matching the real crate's `xla::Error` display usage.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("xla stub: PJRT runtime not available in this build".to_string())
}

/// Host-side literal (tensor) handle.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// First element of a tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// The stub has no runtime to hand out: always fails.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_point_fails_closed() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0f32]).to_tuple1().is_err());
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("stub"));
    }
}
