"""AOT path: lowering emits parseable, version-safe HLO text + manifest."""

import json
import os

import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def lowered():
    return aot.lower_all()


def test_all_artifacts_lowered(lowered):
    assert set(lowered) == {"pcie_latency", "collective_cost", "llm_traffic"}


def test_hlo_is_text_with_entry(lowered):
    for name, text in lowered.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # The 0.5.1 text parser chokes on nothing here; cheap sanity only.
        assert len(text) > 500, name


def test_hlo_shapes_embedded(lowered):
    # Entry signatures must match what rust/src/runtime expects.
    assert f"f32[{aot.PCIE_BATCH}]" in lowered["pcie_latency"]
    assert f"f32[3,{aot.COLL_BATCH}]" in lowered["collective_cost"]
    assert "f32[16]" in lowered["llm_traffic"]


def test_no_serialized_proto_interchange(lowered):
    """Guard the gotcha: we must ship text, not bytes (xla_extension 0.5.1
    rejects jax>=0.5 64-bit-id protos)."""
    for text in lowered.values():
        assert isinstance(text, str)


def test_manifest_roundtrip(tmp_path):
    m = aot.manifest()
    assert m["version"] == aot.MANIFEST_VERSION
    assert m["pcie_latency"]["param_layout"] == list(ref.PCIE_PARAM_LAYOUT)
    assert m["llm_traffic"]["out_layout"] == list(model.TRAFFIC_OUT_LAYOUT)
    p = tmp_path / "manifest.json"
    p.write_text(json.dumps(m))
    assert json.loads(p.read_text()) == m


def test_main_writes_artifacts(tmp_path, monkeypatch, capsys):
    monkeypatch.setattr("sys.argv", ["aot", "--out-dir", str(tmp_path)])
    aot.main()
    names = sorted(os.listdir(tmp_path))
    assert names == [
        "collective_cost.hlo.txt",
        "llm_traffic.hlo.txt",
        "manifest.json",
        "pcie_latency.hlo.txt",
    ]
    for n in names:
        assert (tmp_path / n).stat().st_size > 100
