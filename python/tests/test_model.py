"""L2 model invariants: the LLM communication-volume model."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

GEN3 = jnp.array([16.0, 8.0, 128.0 / 130.0, 24.0, 128.0, 2.0, 6.0, 4.0], jnp.float32)
INTRA = jnp.array([8.0, 500.0, 0.002], jnp.float32)
INTER = jnp.array([8.0, 2000.0, 0.02], jnp.float32)

IDX = {name: i for i, name in enumerate(model.TRAFFIC_OUT_LAYOUT)}


def run(L=32, h=4096, s=2048, b=1, V=50257, tp=8, pp=4, dp=8, bytes_e=2, m=8):
    llm = jnp.array([L, h, s, b, V, tp, pp, dp, bytes_e, m], jnp.float32)
    return np.asarray(model.llm_traffic(llm, GEN3, INTRA, INTER))


def test_output_shape_and_layout():
    out = run()
    assert out.shape == (model.N_TRAFFIC_OUT,)
    assert np.all(np.isfinite(out))
    assert np.all(out >= 0)


def test_frac_inter_definition():
    out = run()
    intra, inter = out[IDX["intra_bytes_per_step"]], out[IDX["inter_bytes_per_step"]]
    assert out[IDX["frac_inter"]] == pytest.approx(inter / (intra + inter), rel=1e-5)


def test_pure_tp_is_all_intra():
    """tp>1, pp=1, dp=1: C5-like, zero inter traffic."""
    out = run(tp=8, pp=1, dp=1)
    assert out[IDX["inter_bytes_per_step"]] == 0.0
    assert out[IDX["frac_inter"]] == 0.0


def test_pure_pp_dp_is_all_inter():
    """tp=1: nothing stays in the node."""
    out = run(tp=1, pp=4, dp=8)
    assert out[IDX["intra_bytes_per_step"]] == 0.0
    assert out[IDX["frac_inter"]] == pytest.approx(1.0)


def test_more_tp_raises_intra_share():
    """Shifting parallelism from PP to TP moves traffic into the node —
    the C4 -> C1 direction of the paper's pattern family."""
    f_low_tp = run(tp=2, pp=16)[IDX["frac_inter"]]
    f_high_tp = run(tp=16, pp=2)[IDX["frac_inter"]]
    assert f_high_tp < f_low_tp


def test_param_count_matches_megatron_estimate():
    out = run(L=32, h=4096, V=50257)
    want = 12 * 32 * 4096**2 + 50257 * 4096
    assert out[IDX["total_params"]] == pytest.approx(want, rel=1e-6)


def test_dp_shard_scales_inversely_with_tp_pp():
    a = run(tp=2, pp=2)[IDX["dp_msg_size_b"]]
    b = run(tp=4, pp=4)[IDX["dp_msg_size_b"]]
    assert a == pytest.approx(4 * b, rel=1e-5)


def test_costs_match_ref_kernels():
    out = run()
    sizes = jnp.array(
        [out[IDX["tp_msg_size_b"]], out[IDX["pp_msg_size_b"]], out[IDX["dp_msg_size_b"]]],
        jnp.float32,
    )
    want_pcie = np.asarray(ref.pcie_latency_ref(sizes, GEN3))
    np.testing.assert_allclose(
        [out[IDX["pcie_tp_msg_ns"]], out[IDX["pcie_pp_msg_ns"]], out[IDX["pcie_dp_msg_ns"]]],
        want_pcie,
        rtol=1e-5,
    )
    want_coll = np.asarray(ref.collective_cost_ref(sizes, INTER))
    assert out[IDX["pp_p2p_ns"]] == pytest.approx(float(want_coll[2, 1]), rel=1e-5)
    assert out[IDX["dp_allreduce_ns"]] == pytest.approx(float(want_coll[0, 2]), rel=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    L=st.integers(1, 128),
    h=st.sampled_from([512, 1024, 4096, 8192]),
    tp=st.sampled_from([1, 2, 4, 8]),
    pp=st.sampled_from([1, 2, 4, 8]),
    dp=st.sampled_from([1, 2, 4, 8]),
    m=st.integers(1, 32),
)
def test_hypothesis_model_invariants(L, h, tp, pp, dp, m):
    out = run(L=L, h=h, tp=tp, pp=pp, dp=dp, m=m)
    assert np.all(np.isfinite(out))
    f = out[IDX["frac_inter"]]
    assert 0.0 <= f <= 1.0
    # Volume accounting is self-consistent.
    total = out[IDX["intra_bytes_per_step"]] + out[IDX["inter_bytes_per_step"]]
    if total > 0:
        assert f == pytest.approx(out[IDX["inter_bytes_per_step"]] / total, rel=1e-4)
