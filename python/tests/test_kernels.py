"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import collective_cost, pcie_latency, ref

GEN3 = np.array([16.0, 8.0, 128.0 / 130.0, 24.0, 128.0, 2.0, 6.0, 4.0], np.float32)
COLL = np.array([8.0, 500.0, 0.01], np.float32)


def _sizes(n, lo=1.0, hi=4 * 1024 * 1024):
    rng = np.random.default_rng(n)
    return rng.uniform(lo, hi, size=n).astype(np.float32)


# ---------------------------------------------------------------- pcie kernel

@pytest.mark.parametrize("n", [1, 7, 128, 1023, 1024, 1025, 4096])
def test_pcie_matches_ref_across_batch_sizes(n):
    sizes = _sizes(n)
    got = pcie_latency(jnp.asarray(sizes), jnp.asarray(GEN3))
    want = ref.pcie_latency_ref(jnp.asarray(sizes), jnp.asarray(GEN3))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_pcie_single_tlp_floor():
    """Messages <= MPS all cost exactly one TLP + one ACK (paper §4.1)."""
    sizes = jnp.array([1.0, 64.0, 127.0, 128.0], jnp.float32)
    out = np.asarray(pcie_latency(sizes, jnp.asarray(GEN3)))
    assert np.all(out == out[0])


def test_pcie_known_value_gen3_x16():
    """Hand-computed 4 KiB Gen3 x16 value: 32 TLPs + 8 ACKs."""
    bytes_per_ns = 16 * 8 * (128.0 / 130.0) / 8.0
    want = 32 * (24 + 128) / bytes_per_ns + 8 * (2 + 6) / bytes_per_ns
    got = float(pcie_latency(jnp.array([4096.0], jnp.float32), jnp.asarray(GEN3))[0])
    assert got == pytest.approx(want, rel=1e-6)


def test_pcie_monotone_in_size():
    sizes = jnp.asarray(np.linspace(1, 1 << 22, 2048, dtype=np.float32))
    out = np.asarray(pcie_latency(sizes, jnp.asarray(GEN3)))
    assert np.all(np.diff(out) >= 0)


def test_pcie_rejects_bad_shapes():
    with pytest.raises(ValueError):
        pcie_latency(jnp.zeros((2, 2), jnp.float32), jnp.asarray(GEN3))
    with pytest.raises(ValueError):
        pcie_latency(jnp.ones((4,), jnp.float32), jnp.zeros((3,), jnp.float32))


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 3000),
    width=st.sampled_from([1.0, 4.0, 8.0, 16.0]),
    datarate=st.sampled_from([8.0, 16.0, 32.0, 64.0]),
    mps=st.sampled_from([128.0, 256.0, 512.0]),
    ack=st.sampled_from([1.0, 4.0, 8.0]),
)
def test_pcie_hypothesis_param_sweep(n, width, datarate, mps, ack):
    params = jnp.array([width, datarate, 128.0 / 130.0, 24.0, mps, 2.0, 6.0, ack], jnp.float32)
    sizes = jnp.asarray(_sizes(n))
    got = pcie_latency(sizes, params)
    want = ref.pcie_latency_ref(sizes, params)
    np.testing.assert_allclose(got, want, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(block=st.sampled_from([8, 64, 256, 1024, 2048]), n=st.integers(1, 2500))
def test_pcie_block_size_invariance(block, n):
    """Tiling choice must not change the numbers."""
    sizes = jnp.asarray(_sizes(n))
    got = pcie_latency(sizes, jnp.asarray(GEN3), block=block)
    want = pcie_latency(sizes, jnp.asarray(GEN3), block=1024)
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------- collective kernel

@pytest.mark.parametrize("n", [1, 3, 255, 256, 257, 2048])
def test_collective_matches_ref_across_batch_sizes(n):
    sizes = _sizes(n)
    got = collective_cost(jnp.asarray(sizes), jnp.asarray(COLL))
    want = ref.collective_cost_ref(jnp.asarray(sizes), jnp.asarray(COLL))
    assert got.shape == (3, n)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_collective_allreduce_is_two_allgathers():
    """Ring AR = reduce-scatter + all-gather: exactly 2x the AG cost."""
    sizes = jnp.asarray(_sizes(64))
    out = np.asarray(collective_cost(sizes, jnp.asarray(COLL)))
    np.testing.assert_allclose(out[0], 2.0 * out[1], rtol=1e-6)


def test_collective_single_device_degenerates():
    """n=1: rings cost nothing, p2p is alpha + size*beta."""
    params = jnp.array([1.0, 500.0, 0.01], jnp.float32)
    sizes = jnp.array([1000.0], jnp.float32)
    out = np.asarray(collective_cost(sizes, params))
    assert out[0, 0] == 0.0 and out[1, 0] == 0.0
    assert out[2, 0] == pytest.approx(500.0 + 10.0)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 1500),
    devs=st.sampled_from([1.0, 2.0, 4.0, 8.0, 64.0]),
    alpha=st.floats(0.0, 1e4),
    beta=st.floats(0.0, 1.0),
)
def test_collective_hypothesis_param_sweep(n, devs, alpha, beta):
    params = jnp.array([devs, alpha, beta], jnp.float32)
    sizes = jnp.asarray(_sizes(n))
    got = collective_cost(sizes, params)
    want = ref.collective_cost_ref(sizes, params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
