"""Unit tests for python/bench_compare.py (regression-threshold edges,
units drift, missing-arm handling, usage errors).

Stdlib only, and runnable both ways:

* ``python3 python/tests/test_bench_compare.py`` (plain-assert runner)
* ``pytest python/tests/test_bench_compare.py``
"""

import importlib.util
import json
import os
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

spec = importlib.util.spec_from_file_location(
    "bench_compare", os.path.join(ROOT, "python", "bench_compare.py")
)
bench_compare = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_compare)


def write_doc(dirname, name, benches, schema="sauron-bench-v1"):
    path = os.path.join(dirname, name)
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"schema": schema, "benches": benches}, f)
    return path


def run_main(argv):
    """Run bench_compare.main() with argv; return its exit code."""
    old = sys.argv
    sys.argv = ["bench_compare.py"] + argv
    try:
        return bench_compare.main()
    finally:
        sys.argv = old


def bench(name, rate=None, mean_ns=None, units=None):
    b = {"name": name}
    if rate is not None:
        b["rate_per_s"] = rate
    if mean_ns is not None:
        b["mean_ns"] = mean_ns
    if units is not None:
        b["units_per_iter"] = units
    return b


def test_rate_regression_boundary_is_inclusive():
    # ratio * max_regression >= 1.0 is OK: fresh exactly 1/max of
    # baseline sits ON the boundary and must pass; epsilon below fails.
    with tempfile.TemporaryDirectory() as d:
        base = write_doc(d, "base.json", [bench("world", rate=100.0)])
        on_boundary = write_doc(d, "on.json", [bench("world", rate=50.0)])
        below = write_doc(d, "below.json", [bench("world", rate=49.9)])
        assert run_main([base, on_boundary, "--max-regression", "2.0"]) == 0
        assert run_main([base, below, "--max-regression", "2.0"]) == 1


def test_mean_ns_fallback_when_no_rate():
    # Without rate_per_s the mean_ns ratio gates, inverted (bigger mean
    # is worse): 100 -> 200 ns at 2.0x is the boundary, 201 ns regresses.
    with tempfile.TemporaryDirectory() as d:
        base = write_doc(d, "base.json", [bench("lat", mean_ns=100.0)])
        on_boundary = write_doc(d, "on.json", [bench("lat", mean_ns=200.0)])
        below = write_doc(d, "below.json", [bench("lat", mean_ns=201.0)])
        assert run_main([base, on_boundary, "--max-regression", "2.0"]) == 0
        assert run_main([base, below, "--max-regression", "2.0"]) == 1


def test_new_and_removed_arms_never_fail():
    # A fresh-only bench has no baseline yet (NEW); a baseline-only
    # bench is machine-dependent or removed. Neither may gate, even at
    # a strict threshold.
    with tempfile.TemporaryDirectory() as d:
        base = write_doc(d, "base.json", [bench("gone", rate=1e9)])
        fresh = write_doc(d, "fresh.json", [bench("added", rate=1.0)])
        assert run_main([base, fresh, "--max-regression", "1.01"]) == 0


def test_units_drift_gates_only_with_flag():
    # Same speed, different deterministic event count: a simulation
    # behavior change. Reported always, fails only under
    # --require-equal-units.
    with tempfile.TemporaryDirectory() as d:
        base = write_doc(d, "base.json", [bench("world", rate=100.0, units=5000.0)])
        fresh = write_doc(d, "fresh.json", [bench("world", rate=100.0, units=5001.0)])
        assert run_main([base, fresh]) == 0
        assert run_main([base, fresh, "--require-equal-units"]) == 1
        # Sub-integer jitter is not a drift (counts are ints in f64).
        close = write_doc(d, "close.json", [bench("world", rate=100.0, units=5000.4)])
        assert run_main([base, close, "--require-equal-units"]) == 0


def test_units_drift_ignored_when_either_side_lacks_units():
    with tempfile.TemporaryDirectory() as d:
        base = write_doc(d, "base.json", [bench("world", rate=100.0, units=5000.0)])
        fresh = write_doc(d, "fresh.json", [bench("world", rate=100.0)])
        assert run_main([base, fresh, "--require-equal-units"]) == 0


def test_odd_file_count_is_usage_error():
    with tempfile.TemporaryDirectory() as d:
        base = write_doc(d, "base.json", [bench("world", rate=100.0)])
        assert run_main([base]) == 2


def test_schema_mismatch_is_parse_error():
    with tempfile.TemporaryDirectory() as d:
        base = write_doc(d, "base.json", [bench("world", rate=100.0)])
        bad = write_doc(d, "bad.json", [bench("world", rate=100.0)], schema="v0")
        assert run_main([base, bad]) == 2


def test_load_indexes_by_name():
    with tempfile.TemporaryDirectory() as d:
        path = write_doc(d, "b.json", [bench("a", rate=1.0), bench("b", mean_ns=2.0)])
        doc = bench_compare.load(path)
        assert set(doc) == {"a", "b"}
        assert doc["a"]["rate_per_s"] == 1.0


def main():
    tests = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for t in tests:
        t()
        print(f"  {t.__name__} ok")
    print(f"test_bench_compare: {len(tests)} tests passed")


if __name__ == "__main__":
    main()
