"""Unit tests for the calibration suite's Python side: the golden
fixture set under fixtures/calibration/ (structure and coverage floor)
and python/calibration_check.py (tolerance math, report cross-check).

Stdlib only, and runnable both ways:

* ``python3 python/tests/test_calibration.py`` (plain-assert runner)
* ``pytest python/tests/test_calibration.py``
"""

import glob
import importlib.util
import json
import os
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
FIXTURE_DIR = os.path.join(ROOT, "fixtures", "calibration")

spec = importlib.util.spec_from_file_location(
    "calibration_check", os.path.join(ROOT, "python", "calibration_check.py")
)
calibration_check = importlib.util.module_from_spec(spec)
spec.loader.exec_module(calibration_check)


def load_fixtures():
    paths = sorted(glob.glob(os.path.join(FIXTURE_DIR, "*.json")))
    assert paths, f"no fixtures in {FIXTURE_DIR}"
    out = []
    for p in paths:
        with open(p, "r", encoding="utf-8") as f:
            out.append((os.path.basename(p), json.load(f)))
    return out


# ------------------------------------------------------- fixture set shape

def test_fixture_coverage_floor():
    # The acceptance floor: >= 3 measured systems, each covering >= 2
    # distinct path types, every fixture carrying both curves.
    by_system = {}
    for name, fx in load_fixtures():
        by_system.setdefault(fx["system"], set()).add(fx["path"])
        assert fx["bandwidth"], f"{name}: no bandwidth curve"
        assert fx["latency"], f"{name}: no latency curve"
    assert len(by_system) >= 3, f"need >= 3 systems, have {sorted(by_system)}"
    for system, paths in by_system.items():
        assert len(paths) >= 2, f"{system}: needs >= 2 path types, has {sorted(paths)}"


def test_fixture_structure():
    valid_paths = {"intra_nvlink", "intra_pcie", "inter_nic"}
    for name, fx in load_fixtures():
        assert fx["schema"] == "sauron-calibration-v1", name
        assert fx["path"] in valid_paths, f"{name}: path {fx['path']}"
        assert 0 < fx["tolerance"] <= 1, f"{name}: tolerance {fx['tolerance']}"
        assert fx["host_overhead_ns"] >= 0, name
        assert "arXiv" in fx["source"], f"{name}: source must carry provenance"
        for curve, value_key in (("bandwidth", "gbs"), ("latency", "us")):
            sizes = [p["size_b"] for p in fx[curve]]
            assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes), (
                f"{name}: {curve} sizes not strictly ascending: {sizes}"
            )
            for p in fx[curve]:
                assert p["size_b"] > 0, name
                assert p[value_key] > 0, f"{name}: {curve} @ {p['size_b']}"
                tol = p.get("tolerance", fx["tolerance"])
                assert 0 < tol <= 1, f"{name}: {curve} @ {p['size_b']} tol {tol}"
                if p.get("known_divergence"):
                    assert p.get("note"), (
                        f"{name}: {curve} @ {p['size_b']}: known divergence needs a note"
                    )


def test_fixture_presets_are_calibrated_systems():
    # Every preset named by a fixture must be one the Rust side
    # declares in presets::CALIBRATED_SYSTEMS (cross-language pin).
    presets_rs = open(
        os.path.join(ROOT, "rust", "src", "config", "presets.rs"), encoding="utf-8"
    ).read()
    for name, fx in load_fixtures():
        assert f'"{fx["preset"]}"' in presets_rs, (
            f"{name}: preset '{fx['preset']}' not found in presets.rs"
        )


def test_csv_header_matches_rust():
    # The checker's expected header must stay byte-identical to the
    # CSV_HEADER the Rust reporter emits.
    calibration_rs = open(
        os.path.join(ROOT, "rust", "src", "calibration", "mod.rs"), encoding="utf-8"
    ).read()
    header = ",".join(calibration_check.EXPECTED_HEADER)
    assert f'"{header}"' in calibration_rs, (
        "python EXPECTED_HEADER drifted from rust CSV_HEADER"
    )


# -------------------------------------------------- tolerance math / checker

def test_recompute_status_boundary_inclusive():
    # rel_err == tolerance passes (mirror of calibration::within).
    rel, status = calibration_check.recompute_status(100.0, 125.0, 0.25, False)
    assert abs(rel - 0.25) < 1e-12 and status == "PASS"
    rel, status = calibration_check.recompute_status(100.0, 125.1, 0.25, False)
    assert status == "FAIL"
    # Symmetric below the expectation.
    _, status = calibration_check.recompute_status(100.0, 75.0, 0.25, False)
    assert status == "PASS"
    _, status = calibration_check.recompute_status(100.0, 74.9, 0.25, False)
    assert status == "FAIL"
    # Known divergence never maps to PASS/FAIL.
    _, status = calibration_check.recompute_status(100.0, 100.0, 0.25, True)
    assert status == "DIVERGENCE"


def row(status, expected=10.0, simulated=10.5, tol=0.25, rel=None, note=""):
    rel = abs(simulated - expected) / expected if rel is None else rel
    return (
        f"leonardo,inter_nic,leonardo,bandwidth,1048576,{expected:.6f},"
        f"{simulated:.6f},GB/s,{tol:.4f},{rel:.6f},{status},{note}"
    )


def write_report(dirname, rows):
    path = os.path.join(dirname, "calibration_report.csv")
    with open(path, "w", encoding="utf-8") as f:
        f.write(",".join(calibration_check.EXPECTED_HEADER) + "\n")
        for r in rows:
            f.write(r + "\n")
    return path


def test_check_report_consistent_pass():
    with tempfile.TemporaryDirectory() as d:
        path = write_report(d, [row("PASS")])
        errors, counts = calibration_check.check_report(path)
        assert errors == [] and counts["PASS"] == 1


def test_check_report_flags_fail_rows():
    with tempfile.TemporaryDirectory() as d:
        path = write_report(d, [row("FAIL", simulated=20.0)])
        errors, counts = calibration_check.check_report(path)
        assert counts["FAIL"] == 1
        assert any("calibration failure" in e for e in errors)


def test_check_report_recomputes_verdicts():
    # A row claiming PASS while its own numbers say FAIL is caught.
    with tempfile.TemporaryDirectory() as d:
        path = write_report(d, [row("PASS", simulated=20.0)])
        errors, _ = calibration_check.check_report(path)
        assert any("recomputed FAIL" in e for e in errors)
    # So is a tampered rel_err column.
    with tempfile.TemporaryDirectory() as d:
        path = write_report(d, [row("PASS", rel=0.0001)])
        errors, _ = calibration_check.check_report(path)
        assert any("recomputed" in e for e in errors)


def test_check_report_strict_gates_divergence():
    with tempfile.TemporaryDirectory() as d:
        path = write_report(
            d, [row("DIVERGENCE", simulated=20.0, note="intra ramp gap")]
        )
        errors, counts = calibration_check.check_report(path)
        assert errors == [] and counts["DIVERGENCE"] == 1
        errors, _ = calibration_check.check_report(path, strict=True)
        assert any("intra ramp gap" in e for e in errors)


def test_check_report_rejects_malformed():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bad.csv")
        with open(path, "w", encoding="utf-8") as f:
            f.write("not,the,header\n")
        try:
            calibration_check.check_report(path)
        except ValueError:
            pass
        else:
            raise AssertionError("malformed header must raise")


def test_main_exit_codes():
    with tempfile.TemporaryDirectory() as d:
        ok = write_report(d, [row("PASS")])
        assert calibration_check.main([ok]) == 0
        bad = write_report(d, [row("FAIL", simulated=20.0)])
        assert calibration_check.main([bad]) == 1
        div = write_report(d, [row("DIVERGENCE", simulated=20.0, note="gap")])
        assert calibration_check.main([div]) == 0
        assert calibration_check.main([div, "--strict"]) == 1
        assert calibration_check.main([os.path.join(d, "missing.csv")]) == 2
        assert calibration_check.main([]) == 2


def main():
    tests = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for t in tests:
        t()
        print(f"  {t.__name__} ok")
    print(f"test_calibration: {len(tests)} tests passed")


if __name__ == "__main__":
    main()
