"""AOT lowering: jax/Pallas -> HLO text artifacts for the Rust runtime.

Interchange format is **HLO text**, NOT serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Artifacts (written to ``--out-dir``, default ``../artifacts``):

* ``pcie_latency.hlo.txt``     — f32[1024] sizes, f32[8] params -> f32[1024]
* ``collective_cost.hlo.txt``  — f32[256] sizes, f32[3] params -> f32[3,256]
* ``llm_traffic.hlo.txt``      — (f32[10], f32[8], f32[3], f32[3]) -> f32[16]
* ``manifest.json``            — shapes + vector layouts, consumed by
  ``rust/src/runtime/artifacts.rs`` to sanity-check at load time.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

PCIE_BATCH = 1024
COLL_BATCH = 256

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all() -> dict[str, str]:
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct

    entries = {
        "pcie_latency": jax.jit(model.pcie_latency_batch).lower(
            spec((PCIE_BATCH,), f32), spec((ref.N_PCIE_PARAMS,), f32)
        ),
        "collective_cost": jax.jit(model.collective_cost_batch).lower(
            spec((COLL_BATCH,), f32), spec((ref.N_COLL_PARAMS,), f32)
        ),
        "llm_traffic": jax.jit(model.llm_traffic).lower(
            spec((model.N_LLM_PARAMS,), f32),
            spec((ref.N_PCIE_PARAMS,), f32),
            spec((ref.N_COLL_PARAMS,), f32),
            spec((ref.N_COLL_PARAMS,), f32),
        ),
    }
    return {name: to_hlo_text(lowered) for name, lowered in entries.items()}


def manifest() -> dict:
    return {
        "version": MANIFEST_VERSION,
        "pcie_latency": {
            "batch": PCIE_BATCH,
            "param_layout": list(ref.PCIE_PARAM_LAYOUT),
        },
        "collective_cost": {
            "batch": COLL_BATCH,
            "param_layout": list(ref.COLL_PARAM_LAYOUT),
        },
        "llm_traffic": {
            "llm_param_layout": list(model.LLM_PARAM_LAYOUT),
            "out_layout": list(model.TRAFFIC_OUT_LAYOUT),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, text in lower_all().items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars -> {path}")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote manifest -> {mpath}")


if __name__ == "__main__":
    main()
