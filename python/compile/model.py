"""L2: Megatron-style LLM communication-volume model (paper §2.4 / §3.4).

The paper's C1–C5 traffic patterns are fixed intra/inter splits motivated by
how much Tensor / Pipeline / Data parallelism an LLM training job uses. This
module makes that motivation executable: given a transformer configuration
and a parallelism layout ``(tp, pp, dp)`` it derives, per training step,

* the per-collective message sizes (TP AllReduce, PP P2P, DP AllReduce),
* the collective counts,
* total intra-node vs inter-node wire bytes (TP rings live inside a node;
  PP stage boundaries and DP gradient rings cross nodes),
* the resulting inter-node traffic fraction (the knob C1–C5 quantise), and
* analytic time estimates via the L1 Pallas kernels
  (:mod:`kernels.pcie_latency`, :mod:`kernels.collective_cost`).

Everything is a flat jax function over f32 vectors so it AOT-lowers to one
HLO module the Rust coordinator executes at sweep-setup time (never per
packet, never Python at runtime).

Transformer accounting (standard Megatron-LM estimates):

* parameters ≈ ``12 L h² + V h`` (attention 4h², MLP 8h², embeddings),
* TP AllReduces: 4 per layer per microbatch (2 fwd + 2 bwd), payload
  ``b·s·h·bytes``,
* PP P2P: 2 transfers (fwd activation + bwd grad) per microbatch per stage
  boundary, payload ``b·s·h·bytes``,
* DP AllReduce: once per step over the rank-local parameter shard
  ``params·bytes / (tp·pp)``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import collective_cost, pcie_latency

# Input layout: LLM configuration vector (f32[10]).
LLM_PARAM_LAYOUT = (
    "num_layers",      # 0: transformer layers L
    "hidden",          # 1: hidden size h
    "seq_len",         # 2: sequence length s
    "microbatch",      # 3: microbatch size b
    "vocab",           # 4: vocabulary size V
    "tp",              # 5: tensor-parallel degree
    "pp",              # 6: pipeline-parallel degree
    "dp",              # 7: data-parallel degree
    "bytes_per_elem",  # 8: activation/grad element size (bf16: 2)
    "num_microbatches",  # 9: microbatches per step m
)
N_LLM_PARAMS = len(LLM_PARAM_LAYOUT)

# Output layout (f32[16]) — must match rust/src/runtime/artifacts.rs.
TRAFFIC_OUT_LAYOUT = (
    "tp_msg_size_b",        # 0
    "pp_msg_size_b",        # 1
    "dp_msg_size_b",        # 2
    "n_tp_collectives",     # 3 per step
    "n_pp_transfers",       # 4 per step
    "n_dp_collectives",     # 5 per step
    "intra_bytes_per_step", # 6 wire bytes inside nodes
    "inter_bytes_per_step", # 7 wire bytes between nodes
    "frac_inter",           # 8 inter / (intra + inter)
    "tp_allreduce_ns",      # 9  (intra α-β)
    "pp_p2p_ns",            # 10 (inter α-β)
    "dp_allreduce_ns",      # 11 (inter α-β)
    "pcie_tp_msg_ns",       # 12 PCIe serialization of one TP message
    "pcie_pp_msg_ns",       # 13
    "pcie_dp_msg_ns",       # 14
    "total_params",         # 15 model parameter count
)
N_TRAFFIC_OUT = len(TRAFFIC_OUT_LAYOUT)


def llm_traffic(
    llm: jnp.ndarray,
    pcie_params: jnp.ndarray,
    coll_intra: jnp.ndarray,
    coll_inter: jnp.ndarray,
) -> jnp.ndarray:
    """Communication volume + cost summary for one training step.

    llm:         f32[10] per LLM_PARAM_LAYOUT.
    pcie_params: f32[8]  per kernels.ref.PCIE_PARAM_LAYOUT.
    coll_intra:  f32[3]  α-β parameters of the intra-node ring (n = tp).
    coll_inter:  f32[3]  α-β parameters of inter-node collectives (n = dp).
    returns:     f32[16] per TRAFFIC_OUT_LAYOUT.
    """
    L = llm[0]
    h = llm[1]
    s = llm[2]
    b = llm[3]
    V = llm[4]
    tp = llm[5]
    pp = llm[6]
    dp = llm[7]
    bytes_e = llm[8]
    m = llm[9]

    total_params = 12.0 * L * h * h + V * h

    act_bytes = b * s * h * bytes_e
    tp_msg = act_bytes                       # one TP AllReduce payload
    pp_msg = act_bytes                       # one PP boundary transfer
    dp_msg = total_params * bytes_e / (tp * pp)  # rank-local gradient shard

    layers_per_stage = L / pp
    n_tp = 4.0 * layers_per_stage * m        # per device group, per step
    n_pp = 2.0 * m * jnp.maximum(pp - 1.0, 0.0)
    n_dp = 1.0

    # Wire bytes per step. TP rings are intra-node by construction (paper
    # §2.4: "tensor parallelism is most effective ... within a single
    # computing node"); PP boundaries and DP gradient rings cross nodes.
    tp_wire = jnp.where(tp > 1.0, 2.0 * (tp - 1.0) / tp * tp_msg, 0.0) * n_tp * tp
    pp_wire = pp_msg * n_pp
    dp_wire = jnp.where(dp > 1.0, 2.0 * (dp - 1.0) / dp * dp_msg, 0.0) * n_dp * dp
    intra_bytes = tp_wire
    inter_bytes = pp_wire + dp_wire
    frac_inter = inter_bytes / jnp.maximum(intra_bytes + inter_bytes, 1.0)

    # Collective completion estimates from the L1 α-β kernel.
    sizes = jnp.stack([tp_msg, pp_msg, dp_msg])
    intra_costs = collective_cost(sizes, coll_intra)  # f32[3,3]
    inter_costs = collective_cost(sizes, coll_inter)
    tp_ar_ns = intra_costs[0, 0]   # allreduce row, tp size
    pp_p2p_ns = inter_costs[2, 1]  # p2p row, pp size
    dp_ar_ns = inter_costs[0, 2]   # allreduce row, dp size

    # PCIe serialization of a single message of each class (L1 kernel).
    pcie_ns = pcie_latency(sizes, pcie_params)

    return jnp.stack(
        [
            tp_msg,
            pp_msg,
            dp_msg,
            n_tp,
            n_pp,
            n_dp,
            intra_bytes,
            inter_bytes,
            frac_inter,
            tp_ar_ns,
            pp_p2p_ns,
            dp_ar_ns,
            pcie_ns[0],
            pcie_ns[1],
            pcie_ns[2],
            total_params,
        ]
    )


def pcie_latency_batch(sizes: jnp.ndarray, params: jnp.ndarray) -> jnp.ndarray:
    """AOT entry: the raw L1 kernel over a fixed-width batch (f32[N] -> f32[N])."""
    return pcie_latency(sizes, params)


def collective_cost_batch(sizes: jnp.ndarray, params: jnp.ndarray) -> jnp.ndarray:
    """AOT entry: the raw α-β kernel over a fixed-width batch (f32[N] -> f32[3,N])."""
    return collective_cost(sizes, params)
