"""Pure-jnp reference oracles for the Pallas kernels.

These implement, verbatim, the analytic models the kernels must match:

* :func:`pcie_latency_ref` — the paper's §3.2 PCIe transaction-timing
  equations (BytesPerNs / TLPTime / DLLPTime / NumberTLPs / NumberACKs /
  LatencyTime), vectorised over a batch of message sizes.
* :func:`collective_cost_ref` — the α-β ring-collective cost model used by
  the L2 LLM communication-volume model (AllReduce / AllGather / P2P).

The pytest + hypothesis suite asserts `assert_allclose(kernel, ref)` over
swept shapes and parameter ranges; the Rust `analytic` module mirrors the
same equations and is cross-checked against the AOT-compiled HLO at test
time, so all four implementations (Pallas, jnp, HLO-via-PJRT, Rust) agree.
"""

from __future__ import annotations

import jax.numpy as jnp

# Layout of the PCIe parameter vector (must match rust/src/runtime/artifacts.rs
# and rust/src/analytic/mod.rs).
PCIE_PARAM_LAYOUT = (
    "width_lanes",      # 0: number of PCIe lanes (e.g. 16)
    "datarate_gbps",    # 1: per-lane raw rate in Gbit/s (Gen3: 8.0)
    "encoding",         # 2: line-code efficiency (Gen3: 128/130)
    "tlp_overhead_b",   # 3: per-TLP framing+header+CRC bytes (e.g. 24)
    "mps_b",            # 4: max payload size per TLP in bytes (e.g. 128)
    "dllp_overhead_b",  # 5: per-DLLP framing overhead bytes (e.g. 2)
    "dllp_size_b",      # 6: DLLP body bytes (e.g. 6)
    "ack_factor",       # 7: TLPs acknowledged per DLLP ACK (e.g. 4)
)
N_PCIE_PARAMS = len(PCIE_PARAM_LAYOUT)

# Layout of the collective parameter vector.
COLL_PARAM_LAYOUT = (
    "n_devices",  # 0: ring size
    "alpha_ns",   # 1: per-step latency in ns
    "beta_ns_b",  # 2: per-byte time in ns/byte (inverse bandwidth)
)
N_COLL_PARAMS = len(COLL_PARAM_LAYOUT)


def pcie_latency_ref(msg_sizes_b: jnp.ndarray, params: jnp.ndarray) -> jnp.ndarray:
    """Paper §3.2: per-message PCIe serialization latency in nanoseconds.

    msg_sizes_b: f32[N] message sizes in bytes (>= 1).
    params:      f32[8] laid out per PCIE_PARAM_LAYOUT.
    returns:     f32[N] LatencyTime in ns.
    """
    width = params[0]
    datarate = params[1]
    encoding = params[2]
    tlp_overhead = params[3]
    mps = params[4]
    dllp_overhead = params[5]
    dllp_size = params[6]
    ack_factor = params[7]

    # Gbit/s per lane * lanes * efficiency -> bytes/ns (1 Gbit/s == 1 bit/ns).
    bytes_per_ns = width * datarate * encoding / 8.0
    tlp_time = (tlp_overhead + mps) / bytes_per_ns
    dllp_time = (dllp_overhead + dllp_size) / bytes_per_ns
    n_tlps = jnp.ceil(msg_sizes_b / mps)
    n_acks = jnp.ceil(n_tlps / ack_factor)
    return n_tlps * tlp_time + n_acks * dllp_time


def collective_cost_ref(sizes_b: jnp.ndarray, params: jnp.ndarray) -> jnp.ndarray:
    """α-β cost (ns) of ring collectives over `n` devices for each size.

    sizes_b: f32[N] total collective payload in bytes.
    params:  f32[3] laid out per COLL_PARAM_LAYOUT.
    returns: f32[3, N] rows = (allreduce, allgather, p2p) completion time ns.
    """
    n = params[0]
    alpha = params[1]
    beta = params[2]

    steps_ar = 2.0 * (n - 1.0)
    bytes_ar = 2.0 * (n - 1.0) / n * sizes_b
    allreduce = steps_ar * alpha + bytes_ar * beta

    steps_ag = n - 1.0
    bytes_ag = (n - 1.0) / n * sizes_b
    allgather = steps_ag * alpha + bytes_ag * beta

    p2p = alpha + sizes_b * beta
    return jnp.stack([allreduce, allgather, p2p], axis=0)
