"""L1 Pallas kernel: α-β ring-collective cost model.

For a batch of collective payload sizes and a (n_devices, α, β) parameter
vector, compute the completion time of the three collective shapes the LLM
traffic model needs (paper §2.4):

* ring **AllReduce** (reduce-scatter + all-gather): ``2(n-1)`` steps, each
  moving ``size/n`` bytes per device,
* ring **AllGather**: ``n-1`` steps of ``size/n`` bytes,
* **P2P** (pipeline-parallel stage boundary): one α + size·β transfer.

Output layout is ``f32[3, N]`` — row 0 allreduce, row 1 allgather, row 2 p2p
(see ``ref.collective_cost_ref``). Tiled like ``pcie_latency``: a 1-D grid
of VMEM-resident BLOCK-lane tiles; the parameter vector is broadcast to all
tiles so the AOT artifact stays reusable across ring sizes and link rates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import N_COLL_PARAMS

BLOCK = 1024


def _kernel(sizes_ref, params_ref, out_ref):
    n = params_ref[0]
    alpha = params_ref[1]
    beta = params_ref[2]
    sizes = sizes_ref[...]

    allreduce = 2.0 * (n - 1.0) * alpha + 2.0 * (n - 1.0) / n * sizes * beta
    allgather = (n - 1.0) * alpha + (n - 1.0) / n * sizes * beta
    p2p = alpha + sizes * beta

    out_ref[0, :] = allreduce
    out_ref[1, :] = allgather
    out_ref[2, :] = p2p


@functools.partial(jax.jit, static_argnames=("block",))
def collective_cost(sizes_b: jnp.ndarray, params: jnp.ndarray, *, block: int = BLOCK) -> jnp.ndarray:
    """Ring-collective costs (ns). sizes_b f32[N], params f32[3] -> f32[3, N]."""
    if sizes_b.ndim != 1:
        raise ValueError(f"sizes_b must be rank-1, got {sizes_b.shape}")
    if params.shape != (N_COLL_PARAMS,):
        raise ValueError(f"params must be f32[{N_COLL_PARAMS}], got {params.shape}")
    n = sizes_b.shape[0]
    padded = (n + block - 1) // block * block
    sizes = jnp.pad(sizes_b.astype(jnp.float32), (0, padded - n), constant_values=1.0)
    out = pl.pallas_call(
        _kernel,
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((N_COLL_PARAMS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((3, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((3, padded), jnp.float32),
        interpret=True,
    )(sizes, params.astype(jnp.float32))
    return out[:, :n]
