"""L1 Pallas kernel: batched PCIe transaction-timing model (paper §3.2).

Given a batch of message sizes, compute the per-message intra-node
serialization latency::

    BytesPerNs = Width * DataRate * Encoding / 8
    TLPTime    = (TLPOverhead + MaxPayloadSize) / BytesPerNs
    DLLPTime   = (DLLPOverhead + DLLPSize) / BytesPerNs
    NumberTLPs = ceil(MessageSize / MaxPayloadSize)
    NumberACKs = ceil(NumberTLPs / AckFactor)
    Latency    = NumberTLPs * TLPTime + NumberACKs * DLLPTime

TPU adaptation (DESIGN.md §Hardware-Adaptation): the model is element-wise
over message descriptors, so we tile the batch into VMEM-resident blocks of
``BLOCK`` lanes and run a 1-D grid over them — the VPU analogue of the
paper's per-transaction host computation. The 8-float parameter vector is a
*runtime* input broadcast to every tile (index_map pinned to block 0) so the
compiled artifact is reusable for any PCIe generation / lane count / MPS
without re-lowering.

``interpret=True`` always: the artifact must run on the CPU PJRT client the
Rust runtime uses (real-TPU lowering emits a Mosaic custom-call the CPU
plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import N_PCIE_PARAMS

# Tile width: one VPU-friendly (8, 128)-shaped f32 block worth of lanes.
BLOCK = 1024


def _kernel(sizes_ref, params_ref, out_ref):
    """One grid step: latency for BLOCK message sizes, params in VMEM."""
    width = params_ref[0]
    datarate = params_ref[1]
    encoding = params_ref[2]
    tlp_overhead = params_ref[3]
    mps = params_ref[4]
    dllp_overhead = params_ref[5]
    dllp_size = params_ref[6]
    ack_factor = params_ref[7]

    bytes_per_ns = width * datarate * encoding / 8.0
    tlp_time = (tlp_overhead + mps) / bytes_per_ns
    dllp_time = (dllp_overhead + dllp_size) / bytes_per_ns

    sizes = sizes_ref[...]
    n_tlps = jnp.ceil(sizes / mps)
    n_acks = jnp.ceil(n_tlps / ack_factor)
    out_ref[...] = n_tlps * tlp_time + n_acks * dllp_time


@functools.partial(jax.jit, static_argnames=("block",))
def pcie_latency(msg_sizes_b: jnp.ndarray, params: jnp.ndarray, *, block: int = BLOCK) -> jnp.ndarray:
    """Batched PCIe latency (ns). msg_sizes_b f32[N], params f32[8] -> f32[N].

    N is padded up to a multiple of ``block`` internally; the pad lanes use a
    size of 1 byte (a valid input) and are sliced off before returning.
    """
    if msg_sizes_b.ndim != 1:
        raise ValueError(f"msg_sizes_b must be rank-1, got {msg_sizes_b.shape}")
    if params.shape != (N_PCIE_PARAMS,):
        raise ValueError(f"params must be f32[{N_PCIE_PARAMS}], got {params.shape}")
    n = msg_sizes_b.shape[0]
    padded = (n + block - 1) // block * block
    sizes = jnp.pad(msg_sizes_b.astype(jnp.float32), (0, padded - n), constant_values=1.0)
    out = pl.pallas_call(
        _kernel,
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            # Whole parameter vector visible to every tile.
            pl.BlockSpec((N_PCIE_PARAMS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=True,
    )(sizes, params.astype(jnp.float32))
    return out[:n]
