# L1: Pallas kernels for the paper's analytic compute hot-spots.
from .pcie_latency import pcie_latency  # noqa: F401
from .collective_cost import collective_cost  # noqa: F401
from . import ref  # noqa: F401
