#!/usr/bin/env python3
"""Test-registration gate: every Rust target file must be declared.

The crate sets ``autotests = false`` (and the equivalent for benches
and examples), so Cargo only builds targets with an explicit
``[[test]]`` / ``[[bench]]`` / ``[[example]]`` entry in Cargo.toml. A
test file added without an entry silently never runs — the worst kind
of green CI. This gate fails when:

* a file in ``rust/tests/*.rs``, ``rust/benches/*.rs`` or
  ``examples/*.rs`` has no matching ``path =`` entry (unregistered:
  the target silently does not build or run);
* an entry's ``path =`` points at a file that does not exist (stale:
  the manifest rots and the next ``cargo`` invocation breaks).

Stdlib only; no TOML parser needed — Cargo.toml target sections are
line-oriented ``name = "..."`` / ``path = "..."`` pairs.

Run from the repository root (CI and scripts/tier1.sh do):
``python3 python/check_tests.py``. Exit 0 = consistent, 1 = drift.
"""

import glob
import os
import re
import sys

SECTION_RE = re.compile(r"^\[\[(test|bench|example)\]\]\s*$")
ANY_SECTION_RE = re.compile(r"^\[")
PATH_RE = re.compile(r'^path\s*=\s*"([^"]+)"\s*$')

# Directories whose .rs files must be registered, per target kind.
GLOBS = {
    "test": "rust/tests/*.rs",
    "bench": "rust/benches/*.rs",
    "example": "examples/*.rs",
}


def registered_paths(manifest):
    """Map target kind -> set of declared ``path`` values."""
    declared = {kind: set() for kind in GLOBS}
    kind = None
    with open(manifest, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            m = SECTION_RE.match(line)
            if m:
                kind = m.group(1)
                continue
            if ANY_SECTION_RE.match(line):
                kind = None  # left the [[test]]-style section
                continue
            if kind:
                m = PATH_RE.match(line)
                if m:
                    declared[kind].add(os.path.normpath(m.group(1)))
    return declared


def main():
    manifest = "Cargo.toml"
    if not os.path.isfile(manifest):
        print("check_tests: run from the repository root", file=sys.stderr)
        return 1
    declared = registered_paths(manifest)
    errors = []
    total = 0
    for kind, pattern in GLOBS.items():
        on_disk = {os.path.normpath(p) for p in glob.glob(pattern)}
        total += len(on_disk)
        for path in sorted(on_disk - declared[kind]):
            errors.append(
                f"{path}: not registered in Cargo.toml — add a [[{kind}]] entry "
                f"(autotests/autobenches are off, so this target never builds)"
            )
        for path in sorted(declared[kind] - on_disk):
            errors.append(
                f"Cargo.toml: [[{kind}]] path '{path}' does not exist on disk "
                f"(stale entry — remove it or restore the file)"
            )
    if errors:
        for e in errors:
            print(e, file=sys.stderr)
        print(f"check_tests: {len(errors)} registration error(s)", file=sys.stderr)
        return 1
    n = {k: len(v) for k, v in declared.items()}
    print(
        f"check_tests: {total} target files all registered "
        f"({n['test']} tests, {n['bench']} benches, {n['example']} examples)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
