#!/usr/bin/env python3
"""Validate a ``sauron calibrate`` report CSV.

Recomputes every row's relative error and verdict from its own
``expected`` / ``simulated`` / ``tolerance`` columns and cross-checks
them against what the simulator emitted, so a bug in the Rust-side
tolerance math (or a hand-edited report) cannot slip a failing point
through CI. The gate (tolerance boundary inclusive, mirroring
``calibration::within``):

* ``rel_err = |simulated - expected| / expected``
* ``PASS``        iff ``rel_err <= tolerance``
* ``FAIL``        iff outside tolerance and not a known divergence
* ``DIVERGENCE``  -> reported, not gated (``--strict`` gates it too)

Exit status: 0 = every row consistent and no gating failure; 1 = a FAIL
row, an emitted-vs-recomputed mismatch, or (with ``--strict``) a
DIVERGENCE row; 2 = unreadable/malformed report.

Usage: ``python3 python/calibration_check.py report.csv [--strict]``
"""

import csv
import sys

EXPECTED_HEADER = [
    "system",
    "path",
    "preset",
    "metric",
    "size_b",
    "expected",
    "simulated",
    "unit",
    "tolerance",
    "rel_err",
    "status",
    "note",
]

# The emitted rel_err column is rounded to 6 decimals; allow exactly
# that much slack (plus float noise) when cross-checking.
REL_ERR_QUANTUM = 5e-7 + 1e-12


def recompute_status(expected, simulated, tolerance, known_divergence):
    """Mirror of calibration::verdict (boundary inclusive)."""
    rel = abs(simulated - expected) / expected
    if known_divergence:
        return rel, "DIVERGENCE"
    return rel, ("PASS" if rel <= tolerance else "FAIL")


def check_report(path, strict=False):
    """Return (errors, counts) for one report file.

    ``errors`` are gating problems (exit 1); malformed input raises
    ValueError (exit 2). ``counts`` maps status -> row count.
    """
    with open(path, "r", encoding="utf-8", newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            raise ValueError(f"{path}: empty report")
        if header != EXPECTED_HEADER:
            raise ValueError(
                f"{path}: unexpected header {header!r} (want {EXPECTED_HEADER!r})"
            )
        errors = []
        counts = {"PASS": 0, "FAIL": 0, "DIVERGENCE": 0}
        for i, row in enumerate(reader, 2):
            if len(row) != len(EXPECTED_HEADER):
                raise ValueError(f"{path}:{i}: {len(row)} columns, want {len(EXPECTED_HEADER)}")
            rec = dict(zip(EXPECTED_HEADER, row))
            where = f"{path}:{i} ({rec['system']}/{rec['path']} {rec['metric']} {rec['size_b']} B)"
            try:
                expected = float(rec["expected"])
                simulated = float(rec["simulated"])
                tolerance = float(rec["tolerance"])
                emitted_rel = float(rec["rel_err"])
            except ValueError:
                raise ValueError(f"{where}: non-numeric field")
            if expected <= 0 or tolerance <= 0 or tolerance > 1:
                raise ValueError(f"{where}: expected/tolerance out of range")
            status = rec["status"]
            if status not in counts:
                raise ValueError(f"{where}: unknown status '{status}'")
            counts[status] += 1
            rel, want = recompute_status(
                expected, simulated, tolerance, status == "DIVERGENCE"
            )
            if abs(rel - emitted_rel) > REL_ERR_QUANTUM:
                errors.append(
                    f"{where}: emitted rel_err {emitted_rel} but recomputed {rel:.6f}"
                )
            if status != "DIVERGENCE" and status != want:
                errors.append(
                    f"{where}: emitted status {status} but recomputed {want} "
                    f"(rel_err {rel:.4f} vs tolerance {tolerance})"
                )
            if status == "FAIL":
                errors.append(
                    f"{where}: calibration failure — sim {simulated} vs published "
                    f"{expected} {rec['unit']} (rel_err {rel:.4f} > tol {tolerance})"
                )
            if strict and status == "DIVERGENCE":
                errors.append(
                    f"{where}: known divergence gated by --strict: {rec['note']}"
                )
        return errors, counts


def main(argv):
    strict = "--strict" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if not paths:
        print(__doc__, file=sys.stderr)
        return 2
    total = {"PASS": 0, "FAIL": 0, "DIVERGENCE": 0}
    errors = []
    for path in paths:
        try:
            errs, counts = check_report(path, strict=strict)
        except (OSError, ValueError) as e:
            print(f"calibration_check: {e}", file=sys.stderr)
            return 2
        errors.extend(errs)
        for k, v in counts.items():
            total[k] += v
    for e in errors:
        print(e, file=sys.stderr)
    n = sum(total.values())
    print(
        f"calibration_check: {n} points ({total['PASS']} pass, {total['FAIL']} fail, "
        f"{total['DIVERGENCE']} known-divergence){' [strict]' if strict else ''}"
    )
    if errors:
        print(f"calibration_check: {len(errors)} gating error(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
