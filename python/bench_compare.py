#!/usr/bin/env python3
"""Compare fresh bench runs against committed baselines.

Used by CI's non-gating perf-smoke job:

    python3 python/bench_compare.py BASELINE.json FRESH.json \
        [BASELINE2.json FRESH2.json ...] --max-regression 2.0

Files are given as (baseline, fresh) pairs so one invocation can cover
both bench suites (BENCH_hotpath.json and BENCH_sweep.json). All files
follow the `sauron-bench-v1` schema written by
`benchkit::Bench::write_json`. A benchmark regresses when its fresh
`rate_per_s` falls below `baseline_rate / max_regression`; benchmarks
without a throughput annotation are compared on `mean_ns` instead
(regression = fresh mean more than `max_regression` times the baseline
mean). Benchmarks present on only one side are reported but never fail
the comparison (machines differ in which optional benches run, e.g. the
PJRT table build). Exit status: 0 = within bounds, 1 = regression,
2 = usage/parse error.

The world benches also record their deterministic work unit
(`units_per_iter` — the scalar-equivalent event count of the config;
all bench arms run telemetry-off). A units mismatch between baseline
and fresh means the *simulation itself* changed behavior, not just its
speed — reported as EVENTS-DRIFT, and a failure when
``--require-equal-units`` is passed (CI does; a drift is expected
exactly once per intentional engine-semantics change, cleared by
regenerating the committed baseline).
"""

import argparse
import json
import sys


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != "sauron-bench-v1":
        raise ValueError(f"{path}: unexpected schema {doc.get('schema')!r}")
    out = {}
    for b in doc.get("benches", []):
        out[b["name"]] = b
    return out


def compare_units(base, fresh):
    """Names whose recorded work units (event counts) drifted."""
    drifted = []
    for name in sorted(set(base) & set(fresh)):
        bu, fu = base[name].get("units_per_iter"), fresh[name].get("units_per_iter")
        if bu is None or fu is None:
            continue
        if abs(bu - fu) > 0.5:  # event counts are integers carried as f64
            print(
                f"  {name:<44} {bu:>14.0f} -> {fu:>14.0f} units  EVENTS-DRIFT"
            )
            drifted.append(name)
    return drifted


def compare_pair(base, fresh, max_regression):
    """Print per-benchmark verdicts; return the list of regressed names."""
    failed = []
    for name in sorted(set(base) | set(fresh)):
        if name not in base or name not in fresh:
            if name in fresh:
                # A newly added bench arm has no committed baseline until
                # the next regeneration: report its rate, never fail.
                rate = fresh[name].get("rate_per_s")
                shown = f"{rate:.0f} /s" if rate else f"{fresh[name].get('mean_ns', 0):.0f} ns"
                print(f"  {name:<44} {shown:>14}  NEW (no baseline yet, not compared)")
            else:
                print(f"  {name:<44} only in baseline (machine-dependent or removed; ignored)")
            continue
        b, f = base[name], fresh[name]
        if "rate_per_s" in b and "rate_per_s" in f and b["rate_per_s"] > 0:
            ratio = f["rate_per_s"] / b["rate_per_s"]
            verdict = "OK" if ratio * max_regression >= 1.0 else "REGRESSION"
            print(
                f"  {name:<44} {b['rate_per_s']:>14.0f} -> {f['rate_per_s']:>14.0f} /s"
                f"  ({ratio:5.2f}x)  {verdict}"
            )
        elif b.get("mean_ns", 0) > 0:
            ratio = b["mean_ns"] / max(f.get("mean_ns", 0), 1e-9)
            verdict = "OK" if ratio * max_regression >= 1.0 else "REGRESSION"
            print(
                f"  {name:<44} {b['mean_ns']:>14.0f} -> {f.get('mean_ns', 0):>14.0f} ns"
                f"  ({ratio:5.2f}x)  {verdict}"
            )
        else:
            continue
        if verdict == "REGRESSION":
            failed.append(name)
    return failed


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "files",
        nargs="+",
        help="alternating baseline/fresh JSON paths: BASE FRESH [BASE2 FRESH2 ...]",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=2.0,
        help="fail when fresh is worse than baseline by more than this factor",
    )
    ap.add_argument(
        "--require-equal-units",
        action="store_true",
        help="fail when a benchmark's recorded work units (telemetry-off "
        "scalar-equivalent event count) differ from the baseline's — a "
        "simulation-behavior change, not a perf change",
    )
    args = ap.parse_args()

    if len(args.files) < 2 or len(args.files) % 2 != 0:
        print(
            "bench_compare: expected an even number of files "
            "(baseline/fresh pairs)",
            file=sys.stderr,
        )
        return 2

    failed = []
    drifted = []
    for base_path, fresh_path in zip(args.files[0::2], args.files[1::2]):
        try:
            base = load(base_path)
            fresh = load(fresh_path)
        except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
            print(f"bench_compare: {e}", file=sys.stderr)
            return 2
        print(f"{base_path} vs {fresh_path}:")
        failed.extend(compare_pair(base, fresh, args.max_regression))
        drifted.extend(compare_units(base, fresh))

    if drifted and args.require_equal_units:
        print(f"bench_compare: {len(drifted)} benchmark(s) changed their "
              f"telemetry-off event counts vs baseline: {', '.join(drifted)} "
              "(simulation behavior changed; regenerate the committed "
              "baseline if intentional)", file=sys.stderr)
        return 1
    if failed:
        print(f"bench_compare: {len(failed)} benchmark(s) regressed >"
              f"{args.max_regression}x: {', '.join(failed)}", file=sys.stderr)
        return 1
    print("bench_compare: all benchmarks within bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
