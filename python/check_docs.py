#!/usr/bin/env python3
"""Docs link checker: every relative link in docs/*.md and every anchor
in README.md / EXPERIMENTS.md must resolve.

Checks, for each markdown file in the set (README.md, EXPERIMENTS.md,
docs/*.md):

* ``[text](relative/path)``   -> the file exists relative to the
  referencing file's directory;
* ``[text](path#anchor)``     -> the file exists AND contains a heading
  whose GitHub-style slug equals ``anchor``;
* ``[text](#anchor)``         -> the same file contains the heading.

``http(s)://`` and ``mailto:`` targets are skipped (the build image is
offline). Exit status: 0 = all links resolve, 1 = broken links found.

Run from the repository root (CI does): ``python3 python/check_docs.py``.
"""

import glob
import os
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def slugify(heading):
    """GitHub-style anchor slug: lowercase, drop punctuation, dash-join."""
    # Strip code/emphasis markers but keep in-word underscores, which
    # GitHub preserves in slugs.
    text = re.sub(r"[`*]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.lower().replace(" ", "-")


def headings_of(path):
    slugs = set()
    in_fence = False
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if m:
                slugs.add(slugify(m.group(1)))
    return slugs


def links_of(path):
    """(target, line_number) pairs outside fenced code blocks."""
    out = []
    in_fence = False
    with open(path, "r", encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                out.append((m.group(1), i))
    return out


def check_file(path, heading_cache):
    errors = []
    base = os.path.dirname(path)
    for target, line in links_of(path):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, anchor = target.partition("#")
        dest = path if not file_part else os.path.normpath(os.path.join(base, file_part))
        if not os.path.isfile(dest):
            errors.append(f"{path}:{line}: broken link '{target}' (no file {dest})")
            continue
        if anchor:
            if not dest.endswith(".md"):
                continue  # anchors into non-markdown files are not checked
            if dest not in heading_cache:
                heading_cache[dest] = headings_of(dest)
            if anchor.lower() not in heading_cache[dest]:
                errors.append(
                    f"{path}:{line}: anchor '#{anchor}' not found in {dest}"
                )
    return errors


def main():
    files = ["README.md", "EXPERIMENTS.md"] + sorted(glob.glob("docs/*.md"))
    missing = [f for f in files if not os.path.isfile(f)]
    if missing:
        print(f"check_docs: missing expected files: {', '.join(missing)}", file=sys.stderr)
        return 1
    heading_cache = {}
    errors = []
    for f in files:
        errors.extend(check_file(f, heading_cache))
    if errors:
        for e in errors:
            print(e, file=sys.stderr)
        print(f"check_docs: {len(errors)} broken link(s)", file=sys.stderr)
        return 1
    n_links = sum(len(links_of(f)) for f in files)
    print(f"check_docs: {len(files)} files, {n_links} links, all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
